"""Soundness of the two-phase global branch-and-bound (shared incumbents).

The contract: an external incumbent bound only ever *adds* prune power, and
only cuts candidates provably no better than a real mapping — so the optimum
*values* (energy, latency, edp) returned by ``explore``/``tcm_map`` are
identical with sharing on or off, loose or tight bounds, serial or parallel.
Also covers the compiled-kernel/vectorized-prune layers the search runs on:
both are required to be bit-identical to their interpreted references.
"""
import numpy as np
import pytest

from repro.core.arch import Arch, MemLevel
from repro.core.einsum import matmul
from repro.core.factor import divisors, prime_factorization
from repro.core.mapper import build_work_units, tcm_map
from repro.core.presets import nvdla_like, small_matmul_suite
from repro.core.search import (MapperStats, cached_curried_model,
                               run_seed_unit)
from repro.core.symbolic import CriteriaKernel, eval_criteria
from repro.core.tileshape import (_grouped_pareto, _pareto_keep,
                                  beam_objective, explore)


def _small_arch(cap=12):
    return Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("GLB", cap, 1, 1, 1e9)), mac_energy=0.5)


def _unit_models(ein, arch, objective="edp"):
    units = build_work_units(ein, arch, objective, True, False, MapperStats())
    return [cached_curried_model(u.einsum, u.arch, u.skeleton) for u in units]


# --------------------------------------------------------------------------
# explore() with external incumbents
# --------------------------------------------------------------------------


def test_explore_loose_vs_tight_incumbent():
    """A loose (inf) and a tight (just-above-optimal) external bound return
    identical optimum values; a bound below the optimum may cut the whole
    unit but never fabricates a better result."""
    ein = matmul("mm", 8, 4, 6)
    arch = _small_arch(16)
    for cm in _unit_models(ein, arch):
        base = explore(cm, objective="edp")
        if base is None:
            continue
        tight = explore(cm, objective="edp",
                        inc_obj=base.edp * (1 + 1e-9))
        assert tight is not None
        assert (tight.energy, tight.latency, tight.edp) == \
            (base.energy, base.latency, base.edp)
        assert tight.stats.n_expanded <= base.stats.n_expanded
        below = explore(cm, objective="edp", inc_obj=base.edp * 0.5)
        if below is not None:  # local beam fallback: a real, valid mapping
            assert below.edp >= base.edp * (1 - 1e-12)


def test_explore_inc_reader_tightens():
    """A reader-supplied bound prunes like a static bound of the same value."""
    ein = matmul("mm", 8, 4, 6)
    arch = _small_arch(16)
    for cm in _unit_models(ein, arch):
        base = explore(cm, objective="edp")
        if base is None:
            continue
        bound = base.edp * (1 + 1e-9)
        via_reader = explore(cm, objective="edp", inc_reader=lambda: bound)
        via_static = explore(cm, objective="edp", inc_obj=bound)
        assert via_reader is not None and via_static is not None
        assert via_reader.edp == via_static.edp == base.edp


def test_beam_objective_is_upper_bound():
    ein = matmul("mm", 8, 8, 4)
    arch = _small_arch(24)
    for cm in _unit_models(ein, arch):
        base = explore(cm, objective="edp")
        obj = beam_objective(cm, "edp")
        if base is not None:
            assert obj >= base.edp * (1 - 1e-12)


def test_run_seed_unit_matches_beam_objective():
    ein = matmul("mm", 4, 4, 4)
    arch = _small_arch()
    units = build_work_units(ein, arch, "edp", True, False, MapperStats())
    for u in units:
        idx, obj, t_curry, t_dive = run_seed_unit(u)
        assert idx == u.index and t_curry >= 0.0 and t_dive >= 0.0
        cm = cached_curried_model(u.einsum, u.arch, u.skeleton)
        assert obj == beam_objective(cm, "edp")


# --------------------------------------------------------------------------
# tcm_map parity: shared incumbents vs the PR-1 per-unit search
# --------------------------------------------------------------------------

SEED_CASES = [
    ("mm442", matmul("mm", 4, 4, 2), _small_arch(12)),
    ("mm444-tight", matmul("mm", 4, 4, 4), _small_arch(6)),
    ("P0", small_matmul_suite()["P0"], nvdla_like()),
    ("D0", small_matmul_suite()["D0"], nvdla_like()),
]


@pytest.mark.parametrize("name,ein,arch", SEED_CASES,
                         ids=[c[0] for c in SEED_CASES])
def test_shared_incumbents_match_unshared_optimum(name, ein, arch):
    best_u, st_u = tcm_map(ein, arch, share_incumbents=False)
    best_s, st_s = tcm_map(ein, arch, share_incumbents=True)
    assert best_u is not None and best_s is not None
    assert (best_s.energy, best_s.latency, best_s.edp) == \
        (best_u.energy, best_u.latency, best_u.edp)
    # sound pruning can only shrink the explored set
    assert st_s.n_expanded <= st_u.n_expanded


def test_shared_parallel_matches_serial_optimum_on_seed_einsums():
    """Shared-incumbent process-pool search returns the PR-1 serial optimum."""
    name, ein, arch = SEED_CASES[2]
    best_u, _ = tcm_map(ein, arch, share_incumbents=False)  # PR-1 behavior
    best_p, _ = tcm_map(ein, arch, workers=2, share_incumbents=True)
    assert best_p is not None
    assert (best_p.energy, best_p.latency, best_p.edp) == \
        (best_u.energy, best_u.latency, best_u.edp)


def test_shared_incumbents_other_objectives():
    ein, arch = SEED_CASES[0][1], SEED_CASES[0][2]
    for objective in ("energy", "latency"):
        best_u, _ = tcm_map(ein, arch, objective=objective,
                            share_incumbents=False)
        best_s, _ = tcm_map(ein, arch, objective=objective)
        assert best_s.objective(objective) == best_u.objective(objective)


# --------------------------------------------------------------------------
# compiled layers: bit-identical to their interpreted references
# --------------------------------------------------------------------------


def test_divisors_match_scan():
    for n in list(range(1, 65)) + [97, 210, 360, 1024, 32768]:
        ref = np.array([d for d in range(1, n + 1) if n % d == 0],
                       dtype=np.int64)
        assert np.array_equal(divisors(n), ref), n


def test_prime_factorization_roundtrip():
    for n in (1, 2, 12, 97, 360, 32768):
        prod = 1
        for p, e in prime_factorization(n):
            prod *= p ** e
        assert prod == max(n, 1)


def test_criteria_kernel_bitwise_matches_eval_criteria():
    rng = np.random.default_rng(0)
    syms = [f"b{i}" for i in range(6)]
    index = {s: i for i, s in enumerate(syms)}
    for _ in range(100):
        crits = []
        for _ in range(int(rng.integers(0, 6))):
            terms = []
            for _ in range(int(rng.integers(0, 5))):
                powers = {}
                for _ in range(int(rng.integers(0, 5))):
                    powers[syms[rng.integers(0, 6)]] = \
                        int(rng.integers(-3, 4) or 1)
                terms.append((float(rng.normal() * 10),
                              tuple(sorted(powers.items()))))
            crits.append(tuple(terms))
        cols = rng.integers(
            1, 9, size=(int(rng.integers(1, 40)), 6)).astype(np.float64)
        a = eval_criteria(crits, index, cols)
        b = CriteriaKernel(crits, index)(cols)
        assert a.shape == b.shape
        assert np.array_equal(a, b)


def test_grouped_pareto_matches_per_group_reference():
    """Vectorized grouped dominance == the np.unique + per-group loop,
    including the floating-point criteria-sum tie regime."""
    rng = np.random.default_rng(1)
    for trial in range(60):
        n = int(rng.integers(1, 400))
        keys = rng.integers(0, 4, size=(n, 2)).astype(np.int64)
        C = rng.integers(0, 4, size=(n, int(rng.integers(1, 7)))
                         ).astype(np.float64)
        if trial % 2:
            # mixed magnitudes force FP-equal sums between distinct rows
            C = C * (10.0 ** rng.integers(-13, 8, size=C.shape[1]))
        _, inv = np.unique(keys, axis=0, return_inverse=True)
        ref = np.ones(n, dtype=bool)
        for g in range(inv.max() + 1):
            gi = np.where(inv == g)[0]
            if len(gi) > 1:
                ref[gi] = _pareto_keep(C[gi])
        assert np.array_equal(_grouped_pareto(C, keys), ref)


# The randomized (hypothesis) incumbent-soundness property lives in
# ``test_incumbent_property.py`` so this module still runs when the optional
# dependency is missing (module-level importorskip skips a whole file).
