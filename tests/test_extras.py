"""Beyond-paper extras: gradient compression, TCM shard planner, autotile."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotile import tcm_matmul_tiles
from repro.core.shard_planner import plan_matmul
from repro.distributed.compression import (compress_decompress,
                                           init_error_feedback, quantized_psum)


def test_compression_roundtrip_small_error():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(300, 7)), jnp.float32)}
    e = init_error_feedback(g)
    deq, e2 = compress_decompress(g, e)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    blk_scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert err <= blk_scale + 1e-6  # one quantization step per block


def test_compression_error_feedback_converges():
    """Averaged over steps, error feedback keeps the cumulative applied
    gradient close to the cumulative true gradient."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(512,)), jnp.float32) * 0.01
    e = init_error_feedback({"g": g_true})
    applied = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, e = compress_decompress({"g": g_true}, e)
        applied = applied + deq["g"]
    np.testing.assert_allclose(np.asarray(applied / 50),
                               np.asarray(g_true), atol=2e-4)


def test_quantized_psum_matches_psum():
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.asarray(np.random.default_rng(2).normal(size=(256,)), jnp.float32)

    def f(x):
        return quantized_psum(x, "d")

    out = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P("d"))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=2e-2,
                               rtol=2e-2)


def test_shard_planner_small_model_prefers_data_parallel():
    """A small matmul should not tensor-parallelize (cell-B finding)."""
    plan = plan_matmul(M=4096, K=512, N=512, data=16, model=16)
    model_par = 1
    for v, f in plan.model_factor.items():
        model_par *= f
    data_par = 1
    for v, f in plan.data_factor.items():
        data_par *= f
    # the batch-like rank m should carry most of the parallelism
    assert plan.data_factor["m"] * plan.model_factor["m"] >= 16


def test_autotile_alignment_and_capacity():
    bm, bk, bn = tcm_matmul_tiles(4096, 4096, 4096)
    assert bm % 128 == 0 and bk % 128 == 0 and bn % 128 == 0
    # working set fits the modeled VMEM
    assert 2 * (bm * bk + bk * bn + bm * bn) <= 16 * 2 ** 20
