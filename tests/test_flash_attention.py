"""flash_attention (pure-JAX, custom_vjp) vs naive attention: fwd + grads."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import flash_attention


def naive_attention(q, k, v, causal=True, window=0, q_offset=0):
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    kk = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vv = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk)
    s = s / math.sqrt(Dh)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("shape", [
    # (B, Sq, Sk, Hq, Hkv, Dh, causal, window)
    (2, 64, 64, 4, 4, 16, True, 0),
    (2, 64, 64, 4, 2, 16, True, 0),     # GQA
    (1, 48, 48, 6, 2, 8, False, 0),     # non-causal, non-pow2 seq
    (2, 64, 64, 4, 1, 16, True, 24),    # local window + MQA
    (1, 1, 96, 4, 2, 16, True, 0),      # decode-style single query
])
def test_forward_matches_naive(shape):
    B, Sq, Sk, Hq, Hkv, Dh, causal, window = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), jnp.float32)
    q_off = Sk - Sq
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_off, q_chunk=16, kv_chunk=16)
    ref = naive_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("hkv,causal,window", [(4, True, 0), (2, True, 0),
                                               (2, False, 0), (1, True, 24)])
def test_grads_match_naive(hkv, causal, window):
    B, S, Hq, Dh = 2, 64, 4, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, S, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, hkv, Dh)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(Dh,)), jnp.float32)

    def f_flash(q, k, v):
        o = flash_attention(q, k, v, causal=causal, window=window,
                            q_chunk=16, kv_chunk=16)
        return jnp.sum(jnp.tanh(o @ w))

    def f_naive(q, k, v):
        return jnp.sum(jnp.tanh(naive_attention(
            q, k, v, causal=causal, window=window) @ w))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{name} mismatch")


def test_kv_valid_masks_tail():
    B, S, H, Dh = 1, 32, 2, 8
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    # valid prefix of 20; garbage tail must not affect the result
    k_g = k.at[:, 20:].set(1e3)
    v_g = v.at[:, 20:].set(1e3)
    out1 = flash_attention(q, k, v, causal=False, kv_valid=20, q_chunk=8,
                           kv_chunk=8)
    out2 = flash_attention(q, k_g, v_g, causal=False, kv_valid=20, q_chunk=8,
                           kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
