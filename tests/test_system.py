"""End-to-end behaviour tests for the paper's system.

The headline behaviours, one assertion each (deep coverage lives in the
dedicated test modules):
  * TCM finds the optimum of a non-trivial mapspace (vs brute force).
  * TCM beats/equals every baseline mapper on the same workload.
  * The curried model agrees with the reference model.
  * The whole production path runs: train a smoke model 3 steps.
"""
import jax
import numpy as np

from repro.core import Arch, MemLevel, SpatialFanout, matmul, tcm_map
from repro.core.baselines import loma_like, timeloop_like
from repro.core.bruteforce import brute_force_optimum


def _arch():
    return Arch(
        "sys",
        (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
         MemLevel("GLB", 96, 1, 1, 1e9)),
        fanouts=(SpatialFanout(above_level=1, dims=(2, 2),
                               multicast_tensor=("A", None),
                               reduce_tensor=(None, "Z")),),
        mac_energy=0.5)


def test_end_to_end_optimal_and_better_than_baselines():
    ein = matmul("mm", 8, 4, 4)
    arch = _arch()
    best, stats = tcm_map(ein, arch)
    bf = brute_force_optimum(ein, arch, keep_unit_loops=False)
    assert abs(best.edp - bf.result.edp) <= 1e-9 * bf.result.edp
    assert stats.log10_total > stats.log10_evaluated  # pruning happened
    for r in (timeloop_like(ein, arch, 300, seed=0),
              loma_like(ein, arch, 300, seed=0)):
        assert best.edp <= r.objective("edp") * (1 + 1e-9)


def test_production_path_smoke():
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticTokens
    from repro.launch.mesh import make_elastic_mesh
    from repro.optim.adamw import OptConfig
    from repro.training.step import init_sharded, make_train_step

    cfg = get_config("mamba2-130m", smoke=True)
    oc = OptConfig(lr=1e-3)
    mesh = make_elastic_mesh(target_model=1)
    params, specs, opt = init_sharded(cfg, oc, mesh)
    step, *_ = make_train_step(cfg, oc, mesh, specs)
    data = SyntheticTokens(DataConfig(global_batch=2, seq_len=64,
                                      vocab=cfg.vocab))
    for _ in range(3):
        params, opt, m = step(params, opt, next(data))
    assert np.isfinite(float(m["loss"]))
