"""Observability subsystem: zero-overhead contract, event merging, exports.

The load-bearing contract is that tracing is *observational*: with
``tracer=None`` (the default) every hot path executes the exact pre-tracing
instruction stream, and with a live tracer the search returns bit-identical
optima and counter stats while additionally emitting a coherent event
stream whose per-criterion prune attribution sums to the ``n_pruned_*``
fields of ``MapperStats`` (the ISSUE-7 acceptance criterion).
"""
import json

import pytest

from repro.core.arch import Arch, MemLevel
from repro.core.einsum import matmul
from repro.core.mapper import tcm_map
from repro.core.presets import small_matmul_suite, tpu_v4i_like
from repro.core.search import MapperStats, stats_from_dict
from repro.obs import (NULL_TRACER, NullTracer, Tracer, active, from_chrome,
                       profile, read_jsonl, read_trace, to_chrome,
                       write_chrome, write_jsonl)
from repro.obs.__main__ import main as obs_main

EIN = matmul("mm", 4, 4, 4)
ARCH = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                  MemLevel("GLB", 12, 1, 1, 1e9)), mac_energy=0.5)

NON_TIMING = lambda st: {k: v for k, v in st.to_dict().items()  # noqa: E731
                         if not k.startswith("t_")}


def prune_sums(events):
    """Sum the per-criterion attribution over all step counter events."""
    out = {"expanded": 0, "pruned_dominated": 0, "pruned_bound": 0,
           "pruned_invalid": 0}
    for ev in events:
        if ev.get("cat") == "step":
            for k in out:
                out[k] += ev.get("args", {}).get(k, 0)
    return out


# --------------------------------------------------------------------------
# tracer primitives
# --------------------------------------------------------------------------


def test_null_tracer_is_inert():
    nt = NullTracer()
    with nt.span("x", cat="driver", a=1):
        nt.instant("i")
        nt.counter("c", v=2)
        nt.complete("done", 0.0)
        nt.extend([{"ph": "i"}])
    assert nt.events == [] and NULL_TRACER.events == []
    assert not nt.enabled


def test_active_normalizes():
    tr = Tracer()
    assert active(None) is None
    assert active(NullTracer()) is None
    assert active(NULL_TRACER) is None
    assert active(tr) is tr


def test_tracer_event_shapes():
    tr = Tracer()
    with tr.span("outer", cat="phase", k=1):
        tr.instant("tick", cat="incumbent", objective=2.0)
        tr.counter("expand", cat="step", expanded=3)
    kinds = {ev["ph"] for ev in tr.events}
    assert kinds == {"X", "i", "C"}
    for ev in tr.events:
        assert set(ev) >= {"ph", "name", "cat", "ts", "pid", "tid", "args"}
        json.dumps(ev)  # JSON-safe (crosses process + file boundaries)
    span = [e for e in tr.events if e["ph"] == "X"][0]
    assert span["dur"] >= 0 and span["args"] == {"k": 1}


# --------------------------------------------------------------------------
# zero-overhead / bit-identical contract (the tentpole invariant)
# --------------------------------------------------------------------------


def test_serial_traced_bit_identical_and_attributed():
    best_u, st_u = tcm_map(EIN, ARCH)
    tr = Tracer()
    best_t, st_t = tcm_map(EIN, ARCH, tracer=tr)
    assert (best_t.energy, best_t.latency, best_t.edp) == \
        (best_u.energy, best_u.latency, best_u.edp)
    assert best_t.mapping == best_u.mapping
    assert NON_TIMING(st_t) == NON_TIMING(st_u)
    # acceptance criterion: per-criterion prune counts sum to MapperStats
    sums = prune_sums(tr.events)
    assert sums["expanded"] == st_t.n_expanded
    assert sums["pruned_dominated"] == st_t.n_pruned_dominated
    assert sums["pruned_bound"] == st_t.n_pruned_bound
    assert sums["pruned_invalid"] == st_t.n_pruned_invalid
    # one driver span closes the trace; phase spans nest under it
    drivers = [e for e in tr.events if e.get("cat") == "driver"]
    assert [d["name"] for d in drivers] == ["tcm_map:mm"]
    assert {e["name"] for e in tr.events if e.get("cat") == "phase"} >= \
        {"enumerate", "search"}


def test_null_tracer_matches_none():
    best_n, st_n = tcm_map(EIN, ARCH, tracer=NullTracer())
    best_u, st_u = tcm_map(EIN, ARCH)
    assert best_n.edp == best_u.edp and best_n.mapping == best_u.mapping
    assert NON_TIMING(st_n) == NON_TIMING(st_u)


def test_pool_unshared_traced_bit_identical():
    best_u, st_u = tcm_map(EIN, ARCH, share_incumbents=False)
    tr = Tracer()
    best_t, st_t = tcm_map(EIN, ARCH, workers=2, share_incumbents=False,
                           tracer=tr)
    assert (best_t.energy, best_t.latency, best_t.edp) == \
        (best_u.energy, best_u.latency, best_u.edp)
    assert best_t.mapping == best_u.mapping
    assert NON_TIMING(st_t) == NON_TIMING(st_u)
    # worker buffers merged: prune attribution still sums exactly
    sums = prune_sums(tr.events)
    assert sums["expanded"] == st_t.n_expanded
    assert sums["pruned_bound"] == st_t.n_pruned_bound


def test_pool_shared_traced_value_parity_and_self_consistent():
    best_u, _ = tcm_map(EIN, ARCH)
    tr = Tracer()
    best_t, st_t = tcm_map(EIN, ARCH, workers=2, tracer=tr)
    assert (best_t.energy, best_t.latency, best_t.edp) == \
        (best_u.energy, best_u.latency, best_u.edp)
    # shared-pool prune counters are scheduling-dependent, but the trace
    # must stay self-consistent with the stats of ITS OWN run
    sums = prune_sums(tr.events)
    assert sums["expanded"] == st_t.n_expanded
    assert sums["pruned_bound"] == st_t.n_pruned_bound
    assert sums["pruned_dominated"] == st_t.n_pruned_dominated
    assert sums["pruned_invalid"] == st_t.n_pruned_invalid


def test_pool_events_merge_in_unit_order():
    tr = Tracer()
    tcm_map(EIN, ARCH, workers=2, share_incumbents=False, tracer=tr)
    units = [e for e in tr.events if e.get("cat") == "unit"]
    assert units, "no unit spans in pool trace"
    indices = [u["args"]["index"] for u in units]
    assert indices == sorted(indices), \
        "worker event buffers must merge in deterministic unit order"


def test_incumbent_timeline_present():
    suite = small_matmul_suite()
    tr = Tracer()
    best, _ = tcm_map(suite["P0"], tpu_v4i_like(), tracer=tr)
    incs = [e for e in tr.events if e.get("cat") == "incumbent"]
    assert incs, "shared-incumbent search must record tightenings"
    assert incs[0]["name"] == "seeded"  # beam-dive seeds the global bound
    objs = [e["args"]["objective"] for e in incs]
    assert objs == sorted(objs, reverse=True)  # monotone tightening
    assert objs[-1] == pytest.approx(best.edp)


# --------------------------------------------------------------------------
# MapperStats wire format (satellite: canonical to_dict / from_dict)
# --------------------------------------------------------------------------


def test_stats_dict_roundtrip():
    _, st = tcm_map(EIN, ARCH)
    wire = st.to_dict()
    json.dumps(wire)  # JSON-safe
    back = stats_from_dict(wire)
    assert isinstance(back, MapperStats)
    assert back.to_dict() == wire
    # forward compatible: unknown keys are dropped, missing keys default
    wire2 = dict(wire, someday_a_new_field=7)
    assert stats_from_dict(wire2).to_dict() == wire
    assert stats_from_dict({"n_expanded": 3}).n_expanded == 3


# --------------------------------------------------------------------------
# exports
# --------------------------------------------------------------------------


def _traced_events():
    tr = Tracer()
    tcm_map(EIN, ARCH, tracer=tr)
    return tr.events


def test_jsonl_roundtrip(tmp_path):
    events = _traced_events()
    p = tmp_path / "t.jsonl"
    write_jsonl(events, p)
    back = read_jsonl(p)
    assert len(back) == len(events)
    assert sorted(map(json.dumps, back)) == sorted(map(json.dumps, events))
    assert read_trace(p) == back  # auto-detect: JSONL


def test_chrome_roundtrip(tmp_path):
    events = _traced_events()
    doc = to_chrome(events)
    assert doc["otherData"]["producer"] == "repro.obs"
    body = [r for r in doc["traceEvents"] if r["ph"] != "M"]
    meta = [r for r in doc["traceEvents"] if r["ph"] == "M"]
    assert len(body) == len(events)
    assert meta and meta[0]["args"]["name"] == "mapper driver"
    assert min(r["ts"] for r in body) == 0.0  # rebased, microseconds
    for r in body:  # Perfetto-loadable: every record fully keyed
        assert set(r) >= {"ph", "name", "cat", "ts", "pid", "tid"}
    back = from_chrome(doc)
    assert len(back) == len(events)
    for a, b in zip(back, sorted(events, key=lambda e: e["ts"])):
        assert a["name"] == b["name"] and a["cat"] == b["cat"]
        assert a["ts"] == pytest.approx(b["ts"], abs=1e-5)
    p = tmp_path / "t.json"
    write_chrome(events, p)
    assert len(read_trace(p)) == len(events)  # auto-detect: Chrome


def test_tracer_save_picks_format(tmp_path):
    tr = Tracer()
    tr.instant("x")
    tr.save(tmp_path / "a.jsonl")
    tr.save(tmp_path / "a.json")
    assert (tmp_path / "a.jsonl").read_text().startswith('{"ph":"i"')
    assert json.loads((tmp_path / "a.json").read_text())["traceEvents"]


# --------------------------------------------------------------------------
# profile report + CLI
# --------------------------------------------------------------------------


def test_profile_report_contents():
    suite = small_matmul_suite()
    tr = Tracer()
    _, st = tcm_map(suite["P0"], tpu_v4i_like(), tracer=tr)
    rep = profile(tr.events)
    assert rep.n_events == len(tr.events)
    assert rep.prune.expanded == st.n_expanded
    assert rep.prune.pruned_total == (st.n_pruned_dominated
                                      + st.n_pruned_bound
                                      + st.n_pruned_invalid)
    assert rep.units and rep.incumbents
    assert rep.units == sorted(rep.units, key=lambda u: -u["dur"])
    text = rep.render(top_k=3)
    assert "phase breakdown" in text
    assert "prune attribution" in text
    assert "incumbent timeline" in text
    assert "most expensive work units" in text


def test_profile_empty():
    rep = profile([])
    assert rep.n_events == 0 and "0 events" in rep.render()


def test_obs_cli(tmp_path, capsys):
    events = _traced_events()
    src = tmp_path / "t.jsonl"
    write_jsonl(events, src)
    assert obs_main(["report", str(src), "--top", "2"]) == 0
    assert "phase breakdown" in capsys.readouterr().out
    assert obs_main([str(src)]) == 0  # bare path implies report
    assert "phase breakdown" in capsys.readouterr().out
    chrome = tmp_path / "t.json"
    assert obs_main(["chrome", str(src), "-o", str(chrome)]) == 0
    assert len(from_chrome(json.loads(chrome.read_text()))) == len(events)
    jl = tmp_path / "back.jsonl"
    assert obs_main(["jsonl", str(chrome), "-o", str(jl)]) == 0
    assert len(read_jsonl(jl)) == len(events)


# --------------------------------------------------------------------------
# consumers: netmap cache/fusion, dse, gap
# --------------------------------------------------------------------------


def test_netmap_trace_cache_and_fusion_events(tmp_path):
    from repro.configs import get_config
    from repro.netmap import MappingCache, map_network

    cfg = get_config("qwen1_5_0_5b", smoke=True)
    arch = tpu_v4i_like()
    cache = MappingCache(root=tmp_path)
    tr_cold = Tracer()
    rep_cold = map_network(cfg, arch, mode="decode", batch=1, seq=16,
                           cache=cache, tracer=tr_cold)
    cold = [e for e in tr_cold.events if e.get("cat") == "cache"]
    assert cold and all(e["name"] in ("miss", "negative") for e in cold)
    fusion = [e for e in tr_cold.events if e.get("cat") == "fusion"]
    assert fusion and all(e["name"] in ("adopted", "rejected")
                          for e in fusion)
    drivers = [e for e in tr_cold.events if e.get("cat") == "driver"
               and e["name"].startswith("map_network:")]
    assert len(drivers) == 1
    assert drivers[0]["args"]["edp"] == pytest.approx(rep_cold.total_edp)

    tr_warm = Tracer()
    rep_warm = map_network(cfg, arch, mode="decode", batch=1, seq=16,
                           cache=cache, tracer=tr_warm)
    warm = [e for e in tr_warm.events if e.get("cat") == "cache"]
    assert warm and all(e["name"] in ("hit", "negative") for e in warm)
    assert rep_warm.total_edp == rep_cold.total_edp
    assert cache.hits > 0 and 0 < cache.hit_rate <= 1.0


def test_dse_trace_events():
    from repro.core.einsum import batched_matmul
    from repro.dse import explore_space, get_space

    tr = Tracer()
    rep = explore_space(get_space("edge-small"),
                        [batched_matmul("fqk", 8, 4, 32, 64),
                         batched_matmul("fav", 8, 4, 64, 32)],
                        collect_mappings=False, tracer=tr)
    dse = [e for e in tr.events if e.get("cat") == "dse"]
    points = [e for e in dse if e["ph"] == "X"]
    instants = [e for e in dse if e["ph"] == "i"]
    assert len(instants) == rep.n_points  # one outcome instant per point
    assert sum(1 for e in instants if e["name"] == "pruned_roofline") == \
        rep.n_pruned_roofline
    assert sum(1 for e in instants if e["name"] == "evaluated") == \
        rep.n_evaluated
    # evaluated + bound-cut + infeasible points get an evaluation span
    assert len(points) == rep.n_points - rep.n_pruned_roofline
    drv = [e for e in tr.events if e.get("cat") == "driver"
           and e["name"].startswith("explore_space:")]
    assert drv and drv[0]["args"]["n_evaluated"] == rep.n_evaluated


def test_gap_trace_baseline_spans():
    from repro.gap.runner import run_gap

    tr = Tracer()
    rep = run_gap({"mm": EIN}, {"a": ARCH}, budgets=[40],
                  baselines=["random"], tracer=tr)
    assert not rep.violations
    spans = [e for e in tr.events if e["name"] == "baseline:random"]
    assert len(spans) == 1
    assert spans[0]["args"]["budgets"] == [40]
    assert spans[0]["args"]["final_gap"] >= 1.0
    # the exact optimum's search telemetry rides along
    assert any(e["name"] == "tcm_map:mm" for e in tr.events)
