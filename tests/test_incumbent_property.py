"""Randomized incumbent-soundness property (hypothesis, optional).

Skips cleanly when ``hypothesis`` is not installed; the deterministic
incumbent-sharing tests live in ``test_incumbent_sharing.py`` and always
run.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: pip install hypothesis "
           "(see requirements.txt)")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.einsum import matmul  # noqa: E402
from repro.core.mapper import tcm_map  # noqa: E402
from repro.core.tileshape import explore  # noqa: E402

from test_incumbent_sharing import _small_arch, _unit_models  # noqa: E402


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    m=st.sampled_from([2, 3, 4]),
    k=st.sampled_from([2, 4]),
    n=st.sampled_from([2, 3]),
    cap=st.sampled_from([4, 8, 16]),
    slack=st.sampled_from([1e-12, 1e-6, 0.1, 10.0]),
)
def test_property_explore_incumbent_soundness(m, k, n, cap, slack):
    """Any external bound strictly above the optimum (deliberately tight)
    returns the same optimum values as an infinitely loose one, for every
    work unit of a random workload; and the full shared-incumbent search
    matches the unshared one."""
    ein = matmul("mm", m, k, n)
    arch = _small_arch(cap)
    for cm in _unit_models(ein, arch):
        base = explore(cm, objective="edp")
        if base is None:
            continue
        tight = explore(cm, objective="edp",
                        inc_obj=base.edp * (1 + slack))
        assert tight is not None
        assert (tight.energy, tight.latency, tight.edp) == \
            (base.energy, base.latency, base.edp)
    best_u, _ = tcm_map(ein, arch, share_incumbents=False)
    best_s, _ = tcm_map(ein, arch)
    assert (best_s is None) == (best_u is None)
    if best_s is not None:
        assert (best_s.energy, best_s.latency, best_s.edp) == \
            (best_u.energy, best_u.latency, best_u.edp)
