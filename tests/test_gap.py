"""The gap harness: gym sampling/moves, runner curves, soundness fuzzing.

Everything here runs at tiny scale — the CI-scale sweeps live behind
``python -m repro.gap`` (gap-smoke job); these tests pin the *contracts*:
sampled points are legal and deterministic, neighbourhood moves stay inside
the mapspace, gap curves never dip below 1.0, and fuzz cases round-trip
through their JSON repro format.
"""
import json
import random

import pytest

from repro.core.arch import Arch, MemLevel, SpatialFanout, arch_to_dict
from repro.core.einsum import (einsum_from_dict, einsum_to_dict, matmul,
                               batched_matmul)
from repro.core.looptree import validate_structure
from repro.core.mapper import tcm_map
from repro.core.refmodel import evaluate
from repro.gap import MapspaceGym, objective_value
from repro.gap.runner import derive_seed, parse_budgets, run_gap
from repro.gap.soundness import (CASE_BUDGET, FuzzCase, check_case, fuzz,
                                 random_case)

REL_EPS = 1e-9


@pytest.fixture(scope="module")
def setup():
    ein = matmul("mm", 16, 8, 4)
    arch = Arch("sp",
                (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                 MemLevel("GLB", 256, 1, 1, 1e9)),
                fanouts=(SpatialFanout(above_level=1, dims=(2, 2),
                                       multicast_tensor=("A", None),
                                       reduce_tensor=(None, "Z")),),
                mac_energy=0.5)
    return ein, arch


def test_gym_samples_are_legal_mappings(setup):
    ein, arch = setup
    gym = MapspaceGym(ein, arch)
    rng = random.Random(0)
    for _ in range(25):
        p = gym.random_point(rng)
        assert p is not None
        m = gym.mapping(p)
        validate_structure(ein, arch, m)
        # the gym's evaluate is refmodel.evaluate on the same mapping
        res = gym.evaluate(p)
        direct = evaluate(ein, arch, m)
        assert res.edp == direct.edp
    assert gym.n_evals == 25


def test_gym_sampling_deterministic(setup):
    ein, arch = setup
    pts_a = [MapspaceGym(ein, arch).random_point(random.Random(7))
             for _ in range(3)]
    pts_b = [MapspaceGym(ein, arch).random_point(random.Random(7))
             for _ in range(3)]
    assert pts_a == pts_b


def test_gym_moves_stay_inside_the_mapspace(setup):
    ein, arch = setup
    gym = MapspaceGym(ein, arch)
    rng = random.Random(1)
    p = gym.random_point(rng)
    for _ in range(40):
        q = gym.perturb(p, rng)
        if q is None:
            continue
        validate_structure(ein, arch, gym.mapping(q))
        c = gym.crossover(p, q, rng)
        validate_structure(ein, arch, gym.mapping(c))
        p = q


def test_objective_value_rejects_unknown_kind(setup):
    ein, arch = setup
    gym = MapspaceGym(ein, arch)
    res = gym.evaluate(gym.random_point(random.Random(2)))
    assert objective_value(res, "edp") == res.edp
    with pytest.raises(ValueError, match="unknown objective kind"):
        objective_value(res, "area")


def test_parse_budgets():
    assert parse_budgets("1e2..1e4") == [100, 1000, 10000]
    assert parse_budgets("100,500") == [100, 500]
    assert parse_budgets("1e3..1e3") == [1000]


def test_derive_seed_is_stable_and_distinct():
    a = derive_seed(0, "QK", "tpu", "sa", 100)
    assert a == derive_seed(0, "QK", "tpu", "sa", 100)
    assert a != derive_seed(0, "QK", "tpu", "sa", 1000)
    assert a != derive_seed(1, "QK", "tpu", "sa", 100)


def test_runner_curves_never_dip_below_optimum(setup):
    ein, arch = setup
    report = run_gap({"mm": ein}, {"toy": arch}, budgets=[60, 120],
                     objectives=("edp", "latency"), seed=3)
    assert not report.violations
    assert len(report.curves) == 2 * 5  # 2 objectives x 5 baselines
    for c in report.curves:
        opt = report.optima[(c.workload, c.arch, c.objective_kind)]
        for p in c.points:
            assert p.objective >= opt * (1 - REL_EPS)
            assert p.gap >= 1 - REL_EPS
    d = report.to_dict()
    assert d["violations"] == []
    json.dumps(d)  # must be JSON-serializable as-is
    assert "soundness" in report.render()


def test_fuzz_small_run_is_clean_and_counts():
    report = fuzz(6, seed=0, verbose=False)
    assert report.ok, [v.detail for v in report.violations]
    assert report.n_cases == 6
    assert report.n_oracle_checked == 6
    assert report.n_baseline_runs == 6 * 3
    json.dumps(report.to_dict())


def test_fuzz_case_roundtrips_through_json():
    case = random_case(random.Random(11))
    d = json.loads(json.dumps(case.to_dict()))
    back = FuzzCase.from_dict(d)
    assert back.seed == case.seed
    assert back.objective == case.objective
    assert back.einsum == case.einsum
    assert arch_to_dict(back.arch) == arch_to_dict(case.arch)
    # the round-tripped case replays to the same verdict
    assert [v.kind for v in check_case(case, oracle=False)[0]] == \
        [v.kind for v in check_case(back, oracle=False)[0]]


def test_einsum_dict_roundtrip():
    from repro.core.einsum import Einsum, TensorSpec
    conv = Einsum("c", (TensorSpec("A", (("p", "r"),)),
                        TensorSpec("W", ("r",)),
                        TensorSpec("Z", ("p",), is_output=True)),
                  {"p": 4, "r": 3})
    for ein in (matmul("mm", 6, 4, 2), batched_matmul("b", 2, 3, 2, 2),
                conv):  # conv exercises the affine (tuple) dim encoding
        assert einsum_from_dict(einsum_to_dict(ein)) == ein


def test_detector_catches_a_planted_false_optimum(setup):
    """End-to-end: feed check_case a claimed optimum that is too low/high by
    construction and the violation machinery must fire.  Rather than
    patching tcm_map, verify the comparison logic directly on a real case:
    the baselines' best can never be strictly below the true optimum, and
    *would* be flagged against a fake optimum above it."""
    ein, arch = setup
    best, _ = tcm_map(ein, arch, objective="edp")
    opt = best.objective("edp")
    from repro.core.baselines import simulated_annealing
    r = simulated_annealing(ein, arch, budget_evals=CASE_BUDGET, seed=9)
    obj = r.objective("edp")
    assert obj >= opt * (1 - REL_EPS)  # sound against the real optimum
    fake_opt = obj * 1.5  # an unsound mapper would have claimed this
    assert obj < fake_opt * (1 - REL_EPS)  # the detector predicate fires
