"""Fused fast-path parity: compiled chain kernels vs per-node reference.

The fused stepper's hot loop runs entirely on compiled
:class:`~repro.core.symbolic.CriteriaKernel` objects (chain-aware lower
bounds, arm-wise dominance criteria) and on the packed wave expansion of
``_expand_wave``.  The contract is *bitwise*: the kernels must reproduce the
interpreted ``eval_criteria`` reference exactly — not merely within
tolerance — at every known-set along the exploration order, because the
search's ``n_expanded`` / ``MapperStats`` anchors are pinned bit-for-bit in
``benchmarks/perf_reference.json``.  The randomized (hypothesis) frontier
property lives in ``test_fused_fastpath_property.py`` so this module still
runs when the optional dependency is missing.
"""
import numpy as np
import pytest

from repro.core.einsum import batched_matmul, matmul
from repro.core.fusion import (FusedWorkload, GroupEdge,
                               enumerate_fused_skeletons)
from repro.core.mapper import build_work_units
from repro.core.presets import nvdla_like, tpu_v4i_like
from repro.core.search import MapperStats, cached_curried_model
from repro.core.symbolic import eval_criteria
from repro.core.tileshape import (_expand_wave, _FusedStepper, _Stepper,
                                  stepper_for)

NVDLA = nvdla_like(tensors=("A", "B", "Z"))
TPU = tpu_v4i_like()


def _attention_pair():
    qk = batched_matmul("qk", 8, 4, 32, 64)
    av = batched_matmul("av", 8, 4, 64, 32)
    return FusedWorkload("qk+av", (qk, av), (GroupEdge(0, 1, "Z", "A"),))


def _ffn_triple():
    up = matmul("up", 4, 64, 128)
    gate = matmul("gate", 4, 64, 128)
    down = matmul("down", 4, 128, 64)
    return FusedWorkload(
        "up+gate+down", (up, gate, down),
        (GroupEdge(0, 2, "Z", "A"), GroupEdge(1, 2, "Z", "A")))


FIXTURES = {
    "attention_pair": (_attention_pair, TPU),
    "ffn_triple": (_ffn_triple, NVDLA),
}


def _fused_steppers(name, objective="edp", limit=3):
    make, arch = FIXTURES[name]
    wl = make()
    for sk in enumerate_fused_skeletons(wl, arch)[:limit]:
        st = stepper_for(cached_curried_model(wl, arch, sk), objective)
        assert isinstance(st, _FusedStepper)
        yield st


def _knowns(st):
    """Every distinct known-set the search can visit, in explore order."""
    for step in range(len(st.explore_order) + 1):
        yield frozenset(st.sites[k].sym for k in st.explore_order[:step])


# --------------------------------------------------------------------------
# chain-LB and dominance kernels: bitwise vs eval_criteria
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fused_lb_kernels_bitwise_vs_reference(name):
    rng = np.random.default_rng(0)
    checked = 0
    for st in _fused_steppers(name):
        n_ext = len(st.sites) + len(st.chain_shapes)
        for known in _knowns(st):
            kernel, slices = st.lb_kernels(known)
            crits, ref_slices = st.lb_criteria(known)
            assert slices == ref_slices
            # one arm group per member, energy bound in column 0
            assert len(slices) == len(st.latency_arm_groups)
            assert slices[0][0] == 1
            ext = rng.integers(1, 17, size=(29, n_ext)).astype(np.float64)
            out = kernel(ext)
            ref = eval_criteria(crits, st.ext_index, ext)
            assert out.shape == ref.shape
            assert np.array_equal(out, ref)
            checked += 1
    assert checked


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fused_dominance_kernels_bitwise_vs_reference(name):
    rng = np.random.default_rng(1)
    checked = 0
    for st in _fused_steppers(name):
        n_sites = len(st.sites)
        for known in _knowns(st):
            kernel = st.dominance_kernel(known)
            crits = st.dominance_criteria(known)
            assert (kernel is None) == (not crits)
            if kernel is None:
                continue
            cols = rng.integers(1, 17, size=(23, n_sites)).astype(np.float64)
            out = kernel(cols)
            ref = eval_criteria(crits, st.sym_index, cols)
            assert out.shape == ref.shape
            assert np.array_equal(out, ref)
            checked += 1
    assert checked


def test_fused_objective_lower_bound_matches_reference_assembly():
    """The stepper's LB assembly (energy x sum of per-member arm maxima)
    equals the same assembly over the interpreted criteria."""
    rng = np.random.default_rng(2)
    for st in _fused_steppers("attention_pair", limit=2):
        cols, rem, fan_rem = st.init_state()
        for step, k in enumerate(st.explore_order):
            known = frozenset(
                st.sites[q].sym for q in st.explore_order[:step])
            out = st.expand(k, cols, rem, fan_rem)
            if out is None:
                break
            cols, rem, fan_rem = out
            if cols.shape[0] > 64:  # keep the walk bounded
                sel = rng.permutation(cols.shape[0])[:64]
                sel.sort()
                cols, rem, fan_rem = cols[sel], rem[sel], fan_rem[sel]
            nk = known | {st.sites[k].sym}
            lb = st.objective_lower_bound(cols, rem, nk)
            crits, slices = st.lb_criteria(nk)
            ext = np.concatenate(
                [cols.astype(np.float64), rem.astype(np.float64)], axis=1)
            ref = eval_criteria(crits, st.ext_index, ext)
            l_lb = sum(ref[:, a:b].max(axis=1) for a, b in slices)
            assert np.array_equal(lb, ref[:, 0] * l_lb)


# --------------------------------------------------------------------------
# packed wave expansion vs the historical per-divisor loop
# --------------------------------------------------------------------------


def _expand_reference(k, divs, chain_cols, fan_cols, cols, rem, fan_rem):
    """Per-divisor Python loop ``_expand_wave`` replaced (order-preserving:
    smallest divisor first, frontier order within each divisor)."""
    outs = []
    for d in divs:
        ok = np.ones(cols.shape[0], dtype=bool)
        for ci in chain_cols:
            ok &= rem[:, ci] % d == 0
        for fc in fan_cols:
            ok &= fan_rem[:, fc] >= d
        idx = np.nonzero(ok)[0]
        if not idx.size:
            continue
        c = cols[idx].copy()
        c[:, k] = d
        r = rem[idx].copy()
        for ci in chain_cols:
            r[:, ci] //= d
        f = fan_rem[idx].copy()
        for fc in fan_cols:
            f[:, fc] //= d
        outs.append((c, r, f))
    if not outs:
        return None
    return tuple(np.concatenate(x) for x in zip(*outs))


def test_expand_wave_matches_per_divisor_reference():
    rng = np.random.default_rng(3)
    divs = np.array([1, 2, 3, 4, 6, 8, 12, 24], dtype=np.int64)
    for _ in range(50):
        n = int(rng.integers(1, 40))
        n_sites, n_chains, n_fans = 5, 4, 3
        cols = rng.integers(1, 9, size=(n, n_sites)).astype(np.int64)
        # quotients drawn from divisors of 24 so chains stay divisible
        rem = divs[rng.integers(0, len(divs), size=(n, n_chains))]
        fan_rem = rng.integers(1, 9, size=(n, n_fans)).astype(np.int64)
        k = int(rng.integers(0, n_sites))
        chain_cols = sorted(rng.permutation(n_chains)[
            :int(rng.integers(1, n_chains + 1))].tolist())
        fan_cols = sorted(rng.permutation(n_fans)[
            :int(rng.integers(0, n_fans + 1))].tolist())
        got = _expand_wave(k, divs, chain_cols, fan_cols,
                           cols, rem, fan_rem)
        ref = _expand_reference(k, divs, chain_cols, fan_cols,
                                cols, rem, fan_rem)
        if ref is None:
            assert got is None
            continue
        for g, r in zip(got, ref):
            assert np.array_equal(g, r)


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fused_stepper_expand_matches_reference_walk(name):
    """Full-explore-order walk: ``st.expand`` (packed) and the per-divisor
    reference produce identical frontiers at every step, absorbers
    included."""
    rng = np.random.default_rng(4)
    for st in _fused_steppers(name, limit=2):
        cols, rem, fan_rem = st.init_state()
        for k in st.explore_order:
            ab = st.absorber.get(k)
            if ab:
                ref_c = cols.copy()
                ref_c[:, k] = rem[:, ab[0]]
                ref_r = rem.copy()
                ref_r[:, list(ab)] = 1
                ref = (ref_c, ref_r, fan_rem)
            else:
                chains = st.site_chains[k]
                shape = st.chain_shapes[chains[0]]
                divs = np.array(
                    [d for d in range(1, shape + 1) if shape % d == 0],
                    dtype=np.int64)
                ref = _expand_reference(
                    k, divs, list(chains), st._site_fan_cols[k],
                    cols, rem, fan_rem)
            got = st.expand(k, cols, rem, fan_rem)
            if ref is None:
                assert got is None
                break
            for g, r in zip(got, ref):
                assert np.array_equal(g, r)
            cols, rem, fan_rem = got
            if cols.shape[0] > 96:  # bound the walk, same rows both paths
                sel = np.sort(rng.permutation(cols.shape[0])[:96])
                cols, rem, fan_rem = cols[sel], rem[sel], fan_rem[sel]


# --------------------------------------------------------------------------
# shared stepper cache: fused and plain models can never collide
# --------------------------------------------------------------------------


def test_shared_stepper_cache_dispatches_per_model():
    """Regression for ``_FusedStepper.get`` delegating into the shared
    ``stepper_cache`` keying: a ``CurriedModel`` and a ``FusedCurriedModel``
    pushed through one *aliased* cache dict must each still receive their
    own implementation, keyed to their own model instance."""
    wl = _attention_pair()
    fused_cm = cached_curried_model(
        wl, TPU, enumerate_fused_skeletons(wl, TPU)[0])
    units = build_work_units(batched_matmul("qk", 8, 4, 32, 64), TPU,
                             "edp", True, False, MapperStats())
    plain_cm = cached_curried_model(
        units[0].einsum, units[0].arch, units[0].skeleton)
    assert getattr(fused_cm, "is_fused", False)
    assert not getattr(plain_cm, "is_fused", False)

    # deliberately alias one cache dict across both models
    shared: dict = {}
    fused_cm.stepper_cache = shared
    plain_cm.stepper_cache = shared
    try:
        st_f = _FusedStepper.get(fused_cm, "edp")
        st_p = _Stepper.get(plain_cm, "edp")
        assert type(st_f) is _FusedStepper and st_f.cm is fused_cm
        assert type(st_p) is _Stepper and st_p.cm is plain_cm
        # the guard re-dispatches on every hand-off, both .get aliases
        assert type(_Stepper.get(fused_cm, "edp")) is _FusedStepper
        assert type(_FusedStepper.get(plain_cm, "edp")) is _Stepper
        # per-model caches hit: same instance back for the same model
        fused_cm.stepper_cache = {}
        plain_cm.stepper_cache = {}
        assert stepper_for(fused_cm, "edp") is stepper_for(fused_cm, "edp")
        assert stepper_for(plain_cm, "edp") is stepper_for(plain_cm, "edp")
    finally:
        # cached_curried_model memoizes across tests: leave clean caches
        fused_cm.stepper_cache = {}
        plain_cm.stepper_cache = {}
