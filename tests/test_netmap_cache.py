"""Persistent mapping cache: exact round-trips, invalidation, recovery."""
import dataclasses

import pytest

from repro.core.einsum import matmul
from repro.core.mapper import tcm_map
from repro.core.presets import nvdla_like
from repro.netmap import cache as cache_mod
from repro.netmap.cache import MappingCache, compute_key

ARCH = nvdla_like(tensors=("A", "B", "Z"))
EINSUM = matmul("probe", 8, 16, 4)


@pytest.fixture(scope="module")
def searched():
    best, stats = tcm_map(EINSUM, ARCH, objective="edp")
    assert best is not None
    return best, stats


def test_roundtrip_identical_result(tmp_path, searched):
    best, stats = searched
    MappingCache(root=tmp_path).put(EINSUM, ARCH, "edp", best, stats,
                                    t_search=1.25)
    hit = MappingCache(root=tmp_path).get(EINSUM, ARCH, "edp")  # from disk
    assert hit is not None
    # identical MappingResult: same mapping nodes, bit-exact floats
    assert hit.result == best
    assert hit.result.mapping == best.mapping
    assert (hit.result.energy, hit.result.latency, hit.result.edp) == (
        best.energy, best.latency, best.edp)
    assert hit.t_search == 1.25
    # search stats survive too (mapspace accounting for warm reports)
    assert hit.stats.log10_total == stats.log10_total
    assert hit.stats.n_final_evals == stats.n_final_evals


def test_changed_inputs_invalidate(tmp_path, searched):
    best, stats = searched
    cache = MappingCache(root=tmp_path)
    cache.put(EINSUM, ARCH, "edp", best, stats)

    assert cache.get(EINSUM, ARCH, "edp") is not None
    # different einsum shape
    assert cache.get(matmul("probe", 16, 16, 4), ARCH, "edp") is None
    # different objective
    assert cache.get(EINSUM, ARCH, "latency") is None
    # different pruning flag
    assert cache.get(EINSUM, ARCH, "edp", prune_partial=False) is None
    # different arch (any field change alters the fingerprint)
    tweaked = dataclasses.replace(ARCH, mac_energy=ARCH.mac_energy * 2)
    assert cache.get(EINSUM, tweaked, "edp") is None
    assert cache.hits == 1 and cache.misses == 4


def test_einsum_name_is_not_part_of_the_key(tmp_path, searched):
    best, stats = searched
    cache = MappingCache(root=tmp_path)
    cache.put(EINSUM, ARCH, "edp", best, stats)
    renamed = matmul("a-different-name", 8, 16, 4)
    assert cache.get(renamed, ARCH, "edp") is not None


def test_code_version_invalidates(tmp_path, searched, monkeypatch):
    best, stats = searched
    MappingCache(root=tmp_path).put(EINSUM, ARCH, "edp", best, stats)
    monkeypatch.setattr(cache_mod, "CACHE_VERSION", cache_mod.CACHE_VERSION + 1)
    stale = MappingCache(root=tmp_path)
    assert len(stale) == 0  # old-version lines ignored, not corrupt
    assert stale.n_corrupt == 0
    assert stale.get(EINSUM, ARCH, "edp") is None


def test_corrupt_lines_are_skipped(tmp_path, searched):
    best, stats = searched
    cache = MappingCache(root=tmp_path)
    cache.put(EINSUM, ARCH, "edp", best, stats)
    other = matmul("other", 4, 8, 2)
    best2, stats2 = tcm_map(other, ARCH, objective="edp")
    cache.put(other, ARCH, "edp", best2, stats2)

    with open(cache.path, "a", encoding="utf-8") as f:
        f.write("this is not json\n")
        f.write('{"v": 1, "key": "truncated-entry"}\n')  # missing fields
        f.write('{"v": 1, "key": "cut off mid-wri')  # crashed append

    recovered = MappingCache(root=tmp_path)
    assert recovered.n_corrupt == 3
    assert len(recovered) == 2
    assert recovered.get(EINSUM, ARCH, "edp").result == best
    assert recovered.get(other, ARCH, "edp").result == best2


def test_structurally_malformed_entry_degrades_to_miss(tmp_path, searched):
    best, stats = searched
    cache = MappingCache(root=tmp_path)
    key = cache.put(EINSUM, ARCH, "edp", best, stats)
    # JSON-valid line with all required keys but a garbage mapping payload
    rec = dict(cache._entries[key])
    rec["mapping"] = 5
    import json

    with open(cache.path, "a", encoding="utf-8") as f:
        f.write(json.dumps(rec) + "\n")  # last write wins on load

    poisoned = MappingCache(root=tmp_path)
    assert poisoned.get(EINSUM, ARCH, "edp") is None  # miss, not a crash
    assert poisoned.n_corrupt == 1 and poisoned.misses == 1


def test_torn_append_quarantined_and_compacted(tmp_path, searched):
    """A crash mid-append leaves a torn trailing line: the loader moves it
    to the .quarantine side file, counts it, and compacts the store so the
    next load is clean."""
    from repro.testing.faults import tear_last_line

    best, stats = searched
    cache = MappingCache(root=tmp_path)
    cache.put(EINSUM, ARCH, "edp", best, stats)
    other = matmul("other", 4, 8, 2)
    best2, stats2 = tcm_map(other, ARCH, objective="edp")
    cache.put(other, ARCH, "edp", best2, stats2)

    tear_last_line(cache.path)
    reloaded = MappingCache(root=tmp_path)
    assert reloaded.n_quarantined == 1
    assert len(reloaded) == 1
    assert reloaded.get(EINSUM, ARCH, "edp").result == best  # survivor
    assert reloaded.get(other, ARCH, "edp") is None  # torn entry -> miss
    # the damage is preserved for post-mortems, not silently dropped
    assert reloaded.quarantine_path.exists()
    assert reloaded.quarantine_path.read_text().strip()
    # compaction rewrote the store: a further load sees a clean file
    clean = MappingCache(root=tmp_path)
    assert clean.n_quarantined == 0 and clean.n_corrupt == 0
    assert len(clean) == 1
    # and the store is usable for new appends after recovery
    clean.put(other, ARCH, "edp", best2, stats2)
    assert MappingCache(root=tmp_path).get(other, ARCH, "edp") is not None


def test_quarantine_accumulates_across_loads(tmp_path, searched):
    best, stats = searched
    cache = MappingCache(root=tmp_path)
    cache.put(EINSUM, ARCH, "edp", best, stats)
    with open(cache.path, "a", encoding="utf-8") as f:
        f.write('{"v": 1, "key": "cut off mi')
    MappingCache(root=tmp_path)  # quarantines + compacts
    with open(cache.path, "a", encoding="utf-8") as f:
        f.write("not json either\n")
    again = MappingCache(root=tmp_path)
    assert again.n_quarantined == 1
    # the side file holds both casualties
    lines = [ln for ln in again.quarantine_path.read_text().splitlines()
             if ln.strip()]
    assert len(lines) == 2


def test_clear(tmp_path, searched):
    best, stats = searched
    cache = MappingCache(root=tmp_path)
    cache.put(EINSUM, ARCH, "edp", best, stats)
    cache.clear()
    assert len(cache) == 0 and not cache.path.exists()
    assert MappingCache(root=tmp_path).get(EINSUM, ARCH, "edp") is None


def test_compute_key_is_stable_and_content_addressed():
    k1 = compute_key(EINSUM, ARCH, "edp")
    k2 = compute_key(matmul("renamed", 8, 16, 4), ARCH, "edp")
    assert k1 == k2  # structural identity, name ignored
    assert compute_key(EINSUM, ARCH, "energy") != k1
    assert len(k1) == 64  # sha256 hex


# --------------------------------------------------------------------------
# fused-group entries
# --------------------------------------------------------------------------


def _group():
    from repro.core.einsum import batched_matmul
    from repro.core.fusion import FusedWorkload, GroupEdge

    qk = batched_matmul("qk", 8, 4, 32, 64)
    av = batched_matmul("av", 8, 4, 64, 32)
    return FusedWorkload("qk+av", (qk, av), (GroupEdge(0, 1, "Z", "A"),))


def test_group_roundtrip_identical(tmp_path):
    from repro.core.fusion import FusedMapping, validate_fused
    from repro.core.mapper import tcm_map_group
    from repro.netmap.cache import compute_group_key

    w = _group()
    best, stats = tcm_map_group(w, ARCH)
    assert best is not None
    MappingCache(root=tmp_path).put_group(w, ARCH, "edp", best, stats,
                                          t_search=2.5)
    hit = MappingCache(root=tmp_path).get_group(w, ARCH, "edp")
    assert hit is not None and hit.t_search == 2.5
    assert isinstance(hit.result.mapping, FusedMapping)
    assert hit.result == best
    assert hit.result.mapping == best.mapping
    validate_fused(w, ARCH, hit.result.mapping)
    # group keys are content-addressed: member names ignored, wiring counted
    k = compute_group_key(w, ARCH, "edp")
    from repro.core.einsum import batched_matmul
    from repro.core.fusion import FusedWorkload, GroupEdge

    renamed = FusedWorkload(
        "other", (batched_matmul("x", 8, 4, 32, 64),
                  batched_matmul("y", 8, 4, 64, 32)),
        (GroupEdge(0, 1, "Z", "A"),))
    assert compute_group_key(renamed, ARCH, "edp") == k
    reshaped = FusedWorkload(
        "other", (batched_matmul("x", 8, 4, 16, 64),
                  batched_matmul("y", 8, 4, 64, 32)),
        (GroupEdge(0, 1, "Z", "A"),))
    assert compute_group_key(reshaped, ARCH, "edp") != k


def test_group_negative_entry_roundtrip(tmp_path):
    w = _group()
    cache = MappingCache(root=tmp_path)
    cache.put_group(w, ARCH, "edp", None, None, t_search=0.7)
    hit = MappingCache(root=tmp_path).get_group(w, ARCH, "edp")
    assert hit is not None and hit.result is None
    assert hit.t_search == 0.7
