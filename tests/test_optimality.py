"""TCM's central claim: the pruned search finds the *optimal* mapping.

We validate against exhaustive enumeration of the unpruned mapspace on small
workloads.  Randomized (hypothesis) workload/architecture draws live in
``test_optimality_property.py``, which skips cleanly when the optional
``hypothesis`` dependency is not installed (see requirements.txt).
"""
import numpy as np
import pytest

from repro.core.arch import Arch, MemLevel, SpatialFanout
from repro.core.bruteforce import brute_force_optimum
from repro.core.einsum import Einsum, TensorSpec, matmul
from repro.core.mapper import tcm_map

RTOL = 1e-9


def _check(ein, arch, objective="edp", keep_unit_loops=False):
    best, _ = tcm_map(ein, arch, objective=objective)
    bf = brute_force_optimum(ein, arch, objective=objective,
                             keep_unit_loops=keep_unit_loops)
    if bf is None:
        assert best is None, "TCM found a mapping where none is valid"
        return None, None
    assert best is not None, "TCM found nothing but a valid mapping exists"
    tcm_obj = best.objective(objective)
    bf_obj = {"edp": bf.result.edp, "energy": bf.result.energy,
              "latency": bf.result.latency}[objective]
    assert tcm_obj <= bf_obj * (1 + RTOL), (
        f"TCM suboptimal: {tcm_obj} > brute force {bf_obj}")
    # TCM's space is a subset of the brute-force space, so it can't be better
    assert tcm_obj >= bf_obj * (1 - RTOL), (
        f"TCM better than brute force?! {tcm_obj} < {bf_obj} (model bug)")
    return best, bf


def test_matmul_two_level():
    ein = matmul("mm", 4, 4, 2)
    arch = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("GLB", 12, 1, 1, 1e9)), mac_energy=0.5)
    _check(ein, arch)


def test_matmul_tight_capacity():
    ein = matmul("mm", 4, 4, 4)
    arch = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("GLB", 6, 1, 1, 1e9)), mac_energy=0.5)
    _check(ein, arch)


def test_matmul_three_level():
    ein = matmul("mm", 2, 4, 2)
    arch = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("GLB", 10, 2, 2, 1e9),
                      MemLevel("RF", 4, 0.2, 0.2, 2e9)), mac_energy=0.5)
    _check(ein, arch)


def test_matmul_spatial():
    ein = matmul("mm", 2, 4, 2)
    arch = Arch(
        "sp",
        (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
         MemLevel("GLB", 24, 1, 1, 1e9)),
        fanouts=(SpatialFanout(above_level=0, dims=(2, 2),
                               multicast_tensor=("A", None),
                               reduce_tensor=(None, "Z")),),
        mac_energy=0.5)
    _check(ein, arch)


def test_objective_energy_and_latency():
    ein = matmul("mm", 4, 4, 2)
    arch = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("GLB", 16, 1, 1, 1e9)), mac_energy=0.5)
    _check(ein, arch, objective="energy")
    _check(ein, arch, objective="latency")


def test_conv_with_affine_dims():
    # keep unit loops in brute force: adjacency (halo/line buffer) matters
    ein = Einsum(
        name="c",
        tensors=(
            TensorSpec("A", (("p", "r"),)),
            TensorSpec("W", ("r",)),
            TensorSpec("Z", ("p",), is_output=True),
        ),
        rank_shapes={"p": 4, "r": 3},
    )
    arch = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("GLB", 8, 1, 1, 1e9)), mac_energy=0.5)
    _check(ein, arch, keep_unit_loops=True)


def test_restricted_level_tensors():
    # a weight-buffer that may only hold B
    ein = matmul("mm", 4, 4, 2)
    arch = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("WB", 8, 0.5, 0.5, 1e9,
                               allowed_tensors=("B",))), mac_energy=0.5)
    _check(ein, arch)
