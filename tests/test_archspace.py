"""Architecture templates, serialization, content keys and validation.

Covers the PR-5 satellites: Arch/SpatialFanout validation error cases,
preset round-trip through the canonical serialization, bit-identical
template re-expression of the hand-written presets, arch_key stability and
per-axis inequality, and the no-collision guarantee for sweep points in the
persistent mapping cache.
"""
import dataclasses

import pytest

from repro.core.arch import (Arch, ArchAxis, ArchSpace, ArchTemplate,
                             MemLevel, SpatialFanout, arch_area_mm2,
                             arch_from_dict, arch_key, arch_to_dict,
                             level_instances)
from repro.core.presets import (nvdla_like, nvdla_template,
                                small_matmul_suite, tpu_v4i_like,
                                tpu_v4i_template, tpu_v5e_like,
                                tpu_v5e_template)

PRESETS = (tpu_v4i_like, nvdla_like, tpu_v5e_like)


def _two_level(fanouts=()):
    return Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("BUF", 4096, 1.0, 1.0, 1e9)),
                fanouts=fanouts)


# --------------------------------------------------------------------------
# Validation (satellite 1)
# --------------------------------------------------------------------------


def test_fanout_above_level_out_of_range_rejected():
    with pytest.raises(ValueError, match="out of range"):
        _two_level(fanouts=(SpatialFanout(above_level=2, dims=(4,)),))
    with pytest.raises(ValueError, match="out of range"):
        _two_level(fanouts=(SpatialFanout(above_level=-1, dims=(4,)),))


def test_duplicate_fanout_below_same_level_rejected():
    with pytest.raises(ValueError, match="duplicate fanout"):
        _two_level(fanouts=(SpatialFanout(above_level=1, dims=(4,)),
                            SpatialFanout(above_level=1, dims=(2,))))


def test_distinct_fanout_levels_accepted():
    a = _two_level(fanouts=(SpatialFanout(above_level=0, dims=(2,)),
                            SpatialFanout(above_level=1, dims=(4,))))
    assert a.total_compute_units == 8
    assert a.fanout_below(1).dims == (4,)


def test_fanout_bad_dims_and_constraint_lengths_rejected():
    with pytest.raises(ValueError, match="dims must be >= 1"):
        SpatialFanout(above_level=0, dims=(4, 0))
    with pytest.raises(ValueError, match="match dims length"):
        SpatialFanout(above_level=0, dims=(4, 2),
                      multicast_tensor=("A",))
    with pytest.raises(ValueError, match="match dims length"):
        SpatialFanout(above_level=0, dims=(4, 2),
                      reduce_tensor=("Z", None, None))


# --------------------------------------------------------------------------
# Serialization + preset round-trip (satellite 2)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("preset", PRESETS)
def test_preset_serialization_round_trip(preset):
    a = preset()
    b = arch_from_dict(arch_to_dict(a))
    assert b == a
    assert arch_key(b) == arch_key(a)


def test_serialization_handles_inf_and_allowed_tensors():
    a = tpu_v4i_like()
    d = arch_to_dict(a)
    assert d["levels"][0]["capacity"] == "inf"  # strict-JSON safe
    assert d["levels"][2]["allowed_tensors"] == ["A", "Z"]
    import json
    b = arch_from_dict(json.loads(json.dumps(d)))
    assert b == a


def test_presets_bit_identical_through_template():
    """The template path must reproduce the historical hand-written Arch
    exactly — same values, same float bit patterns (repr equality)."""
    legacy = Arch(
        name="nvdla-like",
        levels=(
            MemLevel("DRAM", float("inf"), 200.0, 200.0, 12.5e9),
            MemLevel("BUF", 32 * 2 ** 10, 1.2, 1.2, 256e9),
        ),
        fanouts=(
            SpatialFanout(above_level=1, dims=(32, 192),
                          multicast_tensor=("A", None),
                          reduce_tensor=(None, "Z")),
        ),
        mac_energy=0.3,
        frequency=1e9,
    )
    templated = nvdla_like()
    assert templated == legacy
    assert repr(templated) == repr(legacy)


@pytest.mark.parametrize("template,anchor_caps", [
    (tpu_v4i_template, {("capacity", "GLB"): 64 * 2 ** 20,
                        ("capacity", "LB"): 2 * 2 ** 20}),
    (nvdla_template, {("capacity", "BUF"): 32 * 2 ** 10}),
    (tpu_v5e_template, {("capacity", "VMEM"): 16 * 2 ** 20}),
])
def test_instantiate_at_anchor_is_bit_identical(template, anchor_caps):
    t = template()
    base = t.instantiate()
    assert base == t.base and repr(base) == repr(t.base)
    # overriding with the anchor value itself skips scaling entirely
    at_anchor = t.instantiate(anchor_caps)
    assert at_anchor.levels == base.levels
    assert repr(at_anchor.levels) == repr(base.levels)


# --------------------------------------------------------------------------
# arch_key (satellite 3)
# --------------------------------------------------------------------------


def test_arch_key_ignores_name_and_field_order():
    a = nvdla_like()
    renamed = dataclasses.replace(a, name="totally-different")
    assert arch_key(renamed) == arch_key(a)
    # reorder every dict's keys; the canonical (sorted) encoding is stable
    d = arch_to_dict(a)

    def reorder(x):
        if isinstance(x, dict):
            return {k: reorder(x[k]) for k in reversed(list(x))}
        if isinstance(x, list):
            return [reorder(v) for v in x]
        return x

    assert arch_key(arch_from_dict(reorder(d))) == arch_key(a)


def test_arch_key_int_float_spellings_agree():
    a = _two_level()
    b = Arch("a", (MemLevel("DRAM", float("inf"), 100.0, 100.0, 1e8),
                   MemLevel("BUF", 4096.0, 1.0, 1.0, 1e9)))
    assert a == b
    assert arch_key(a) == arch_key(b)


def test_arch_key_differs_on_every_swept_axis():
    t = nvdla_template(tensors=("A", "B", "Z"))
    base = t.instantiate()
    variants = {
        "base": base,
        "capacity": t.instantiate({("capacity", "BUF"): 64 * 2 ** 10}),
        "fanout": t.instantiate({("fanout", 0): (16, 96)}),
        "mac_energy": dataclasses.replace(base, mac_energy=0.4),
        "read_energy": dataclasses.replace(base, levels=(
            base.levels[0],
            dataclasses.replace(base.levels[1], read_energy=2.4))),
        "frequency": dataclasses.replace(base, frequency=2e9),
    }
    tpu = tpu_v4i_template()
    variants["level_removed"] = tpu.instantiate({("level", "REG"): False})
    variants["tpu_base"] = tpu.instantiate()
    keys = {name: arch_key(a) for name, a in variants.items()}
    assert len(set(keys.values())) == len(keys), keys


def test_sweep_points_never_collide_in_mapping_cache():
    """Two distinct sweep points must hash to distinct persistent-cache
    keys for the same einsum — a warm DSE sweep can never serve one
    point's optimum for another."""
    from repro.netmap.cache import compute_key

    qk = small_matmul_suite()["QK"]
    space = ArchSpace(
        name="s", template=nvdla_template(tensors=("A", "B", "Z")),
        axes=(ArchAxis("capacity", "BUF", (8 * 2 ** 10, 32 * 2 ** 10)),
              ArchAxis("fanout", 0, ((16, 96), (32, 192)))))
    points, _ = space.materialize()
    assert len(points) == 4
    cache_keys = {compute_key(qk, p.arch, "edp") for p in points}
    assert len(cache_keys) == len(points)
    assert len({p.key for p in points}) == len(points)


def test_cache_key_is_arch_content_addressed():
    """The inverse guarantee: identical hardware under different names
    (a DSE-derived point vs the preset it equals) shares ONE cache entry."""
    from repro.netmap.cache import compute_key

    qk = small_matmul_suite()["QK"]
    a = nvdla_like(tensors=("A", "B", "Z"))
    renamed = dataclasses.replace(a, name="edge@capacity:BUF=32768")
    assert compute_key(qk, renamed, "edp") == compute_key(qk, a, "edp")


# --------------------------------------------------------------------------
# Template instantiation semantics
# --------------------------------------------------------------------------


def test_capacity_scaling_follows_anchor_exponents():
    t = nvdla_template()
    base = t.base.levels[1]
    quad = t.instantiate({("capacity", "BUF"): base.capacity * 4})
    lvl = quad.levels[1]
    assert lvl.capacity == base.capacity * 4
    assert lvl.read_energy == pytest.approx(base.read_energy * 2.0)  # 4**0.5
    assert lvl.write_energy == pytest.approx(base.write_energy * 2.0)
    assert lvl.bandwidth == pytest.approx(base.bandwidth * 2.0)
    # DRAM anchor untouched
    assert quad.levels[0] == t.base.levels[0]


def test_instantiate_rejects_bad_targets_and_backing_sweeps():
    t = nvdla_template()
    with pytest.raises(KeyError):
        t.instantiate({("capacity", "NOPE"): 1024})
    with pytest.raises(KeyError):
        t.instantiate({("fanout", 3): (2, 2)})
    with pytest.raises(ValueError, match="backing store"):
        t.instantiate({("capacity", "DRAM"): 1024})
    with pytest.raises(ValueError, match="backing store"):
        t.instantiate({("level", "DRAM"): False})
    with pytest.raises(ValueError, match="rank"):
        t.instantiate({("fanout", 0): (32,)})


def test_level_removal_remaps_fanouts():
    base = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                      MemLevel("L1", 65536, 2.0, 2.0, 1e9),
                      MemLevel("L2", 4096, 1.0, 1.0, 1e9)),
                fanouts=(SpatialFanout(above_level=2, dims=(8,)),))
    t = ArchTemplate(base=base)
    a = t.instantiate({("level", "L1"): False})
    assert [l.name for l in a.levels] == ["DRAM", "L2"]
    assert a.fanouts[0].above_level == 1  # still below L2
    assert a.total_compute_units == 8
    # a capacity override for the removed level is ignored, not an error
    b = t.instantiate({("level", "L1"): False, ("capacity", "L1"): 1024})
    assert b == a


def test_level_removal_collision_is_invalid_point():
    # tpu template has fanouts below GLB *and* LB; removing LB would land
    # the MAC array on GLB next to the 4-PE fanout — structurally invalid,
    # so the point must be rejected (and counted by ArchSpace.materialize).
    t = tpu_v4i_template()
    with pytest.raises(ValueError, match="duplicate fanout"):
        t.instantiate({("level", "LB"): False})
    space = ArchSpace(name="s", template=t,
                      axes=(ArchAxis("level", "LB", (True, False)),))
    pts, counters = space.materialize()
    assert len(pts) == 1 and counters["n_invalid"] == 1


def test_space_rejects_bad_axis_targets_eagerly():
    """A typo'd axis target fails at space construction, not as an
    all-invalid (silently empty) sweep."""
    t = nvdla_template()
    with pytest.raises(KeyError, match="GLBB"):
        ArchSpace(name="s", template=t,
                  axes=(ArchAxis("capacity", "GLBB", (1024,)),))
    with pytest.raises(KeyError, match="fanout"):
        ArchSpace(name="s", template=t,
                  axes=(ArchAxis("fanout", 3, ((2, 2),)),))
    with pytest.raises(ValueError, match="duplicate axis"):
        ArchSpace(name="s", template=t,
                  axes=(ArchAxis("capacity", "BUF", (1024,)),
                        ArchAxis("capacity", "BUF", (2048,))))


def test_space_budget_filters_and_dedup():
    t = nvdla_template()
    space = ArchSpace(
        name="s", template=t,
        axes=(ArchAxis("fanout", 0, ((16, 96), (32, 192), (64, 384))),),
        pe_budget=32 * 192)
    pts, counters = space.materialize()
    assert [p.arch.total_compute_units for p in pts] == [1536, 6144]
    assert counters["n_over_pe_budget"] == 1
    tight = ArchSpace(name="s", template=t,
                      axes=(ArchAxis("fanout", 0, ((16, 96), (32, 192))),),
                      area_budget_mm2=1.0)
    pts2, c2 = tight.materialize()
    assert len(pts2) == 1 and c2["n_over_area_budget"] == 1
    # duplicate coordinates (same derived arch) are deduped by content key
    dup = ArchSpace(name="s", template=t,
                    axes=(ArchAxis("capacity", "BUF",
                                   (32 * 2 ** 10, 32 * 2 ** 10.0)),))
    pts3, c3 = dup.materialize()
    assert len(pts3) == 1 and c3["n_duplicates"] == 1


def test_area_model_counts_instances_and_macs():
    a = _two_level(fanouts=(SpatialFanout(above_level=0, dims=(4,)),))
    assert level_instances(a, 0) == 1
    assert level_instances(a, 1) == 4
    from repro.core.arch import AREA_PER_MAC_MM2, AREA_PER_WORD_MM2
    expected = 4 * 4096 * AREA_PER_WORD_MM2 + 4 * AREA_PER_MAC_MM2
    assert arch_area_mm2(a) == pytest.approx(expected)
