"""Randomized optimality checks (hypothesis property tests).

Skips cleanly when the optional ``hypothesis`` dependency is not installed;
``pip install hypothesis`` (or ``pip install -r requirements.txt``) enables
it.  The deterministic optimality tests live in ``test_optimality.py`` and
always run.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: pip install hypothesis "
           "(see requirements.txt)")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.arch import Arch, MemLevel  # noqa: E402
from repro.core.einsum import matmul  # noqa: E402

from test_optimality import _check  # noqa: E402


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    m=st.sampled_from([2, 3, 4]),
    k=st.sampled_from([2, 4]),
    n=st.sampled_from([2, 3]),
    cap=st.sampled_from([4, 8, 16, 64]),
    dram_e=st.sampled_from([50.0, 200.0]),
    glb_e=st.sampled_from([0.5, 2.0]),
    bw_ratio=st.sampled_from([5.0, 50.0]),
)
def test_property_tcm_matches_bruteforce(m, k, n, cap, dram_e, glb_e, bw_ratio):
    ein = matmul("mm", m, k, n)
    arch = Arch("a", (
        MemLevel("DRAM", float("inf"), dram_e, dram_e, 1e9 / bw_ratio),
        MemLevel("GLB", cap, glb_e, glb_e, 1e9)), mac_energy=0.5)
    _check(ein, arch)
