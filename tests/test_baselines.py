"""Baseline mappers: sanity + the paper's qualitative claim (TCM <= baselines)."""
import numpy as np
import pytest

from repro.core.arch import Arch, MemLevel, SpatialFanout
from repro.core.baselines import (evolutionary, loma_like,
                                  simulated_annealing, timeloop_like)
from repro.core.einsum import matmul
from repro.core.looptree import validate_structure
from repro.core.mapper import tcm_map


@pytest.fixture(scope="module")
def setup():
    ein = matmul("mm", 64, 32, 16)
    arch = Arch(
        "sp",
        (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
         MemLevel("GLB", 2048, 1, 1, 1e9)),
        fanouts=(SpatialFanout(above_level=1, dims=(8, 8),
                               multicast_tensor=("A", None),
                               reduce_tensor=(None, "Z")),),
        mac_energy=0.5)
    return ein, arch


def test_timeloop_like_finds_valid(setup):
    ein, arch = setup
    r = timeloop_like(ein, arch, budget_evals=200, seed=1)
    assert r.n_valid > 0
    assert r.best is not None and r.best.valid


def test_hint_beats_pure_random_usually(setup):
    ein, arch = setup
    rnd = timeloop_like(ein, arch, budget_evals=300, seed=2)
    hint = timeloop_like(ein, arch, budget_evals=300, seed=2,
                         full_spatial_hint=True)
    # full-utilization hint should not be (much) worse on this workload
    assert hint.objective() <= rnd.objective() * 1.5


def test_loma_like_valid(setup):
    ein, arch = setup
    r = loma_like(ein, arch, budget_evals=200, lpf_limit=3, seed=3)
    assert r.best is not None and r.best.valid


def test_tcm_at_least_as_good_as_all_baselines(setup):
    """The paper's Table III qualitative result: TCM (optimal) <= baselines."""
    ein, arch = setup
    best, _ = tcm_map(ein, arch)
    assert best is not None
    for r in (timeloop_like(ein, arch, 500, seed=4),
              timeloop_like(ein, arch, 500, seed=4, full_spatial_hint=True),
              loma_like(ein, arch, 500, lpf_limit=3, seed=4),
              simulated_annealing(ein, arch, 500, seed=4),
              evolutionary(ein, arch, 500, seed=4)):
        assert best.edp <= r.objective("edp") * (1 + 1e-9)


def test_sa_and_ga_find_valid_structures(setup):
    ein, arch = setup
    for fn in (simulated_annealing, evolutionary):
        r = fn(ein, arch, budget_evals=200, seed=5)
        assert r.n_valid > 0
        assert r.best is not None and r.best.valid
        assert r.n_evaluated <= 200 + 1  # budget accounting
        validate_structure(ein, arch, r.best_mapping)


def test_objective_rejects_unknown_kind(setup):
    ein, arch = setup
    r = timeloop_like(ein, arch, budget_evals=20, seed=6)
    with pytest.raises(ValueError, match="unknown objective kind"):
        r.objective("power")
    # the same check fires up front, before any search is spent
    for fn in (timeloop_like, loma_like, simulated_annealing, evolutionary):
        with pytest.raises(ValueError, match="unknown objective kind"):
            fn(ein, arch, budget_evals=10, seed=6, objective="power")


def test_all_baselines_deterministic_under_seed(setup):
    ein, arch = setup
    for fn, kwargs in ((timeloop_like, {}),
                       (loma_like, {"lpf_limit": 3}),
                       (simulated_annealing, {}),
                       (evolutionary, {})):
        a = fn(ein, arch, budget_evals=150, seed=7, **kwargs)
        b = fn(ein, arch, budget_evals=150, seed=7, **kwargs)
        assert a.objective("edp") == b.objective("edp")
        assert a.n_evaluated == b.n_evaluated
        assert a.n_valid == b.n_valid
        assert a.best_mapping == b.best_mapping
        c = fn(ein, arch, budget_evals=150, seed=8, **kwargs)
        # different seed must give a different search *trace* (the best
        # objective may coincide; the valid-sample count rarely does)
        assert (a.n_valid, a.best_mapping) != (c.n_valid, c.best_mapping) or \
            a.objective("edp") == c.objective("edp")
