"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step and a prefill+decode step on CPU; asserts shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm

B, S = 2, 64


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, 8, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(0)
    params, specs = lm.init(cfg, jax.random.PRNGKey(0))
    # specs mirror params
    assert set(specs.keys()) <= set(params.keys()) | {"groups"}
    batch = _batch(cfg, rng)
    loss, parts = jax.jit(lambda p, b: lm.loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    logits, _, _ = lm.forward(cfg, params, batch["tokens"],
                              embeds=batch.get("embeds"),
                              enc_frames=batch.get("enc_frames"))
    exp_s = S + (batch["embeds"].shape[1] if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: logits NaN"


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step(arch):
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(1)
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)

    def f(p):
        return lm.loss_fn(cfg, p, batch)[0]

    g = jax.jit(jax.grad(f))(params)
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.isfinite(x).all()) for x in flat), f"{arch}: NaN grad"
    assert any(float(jnp.abs(x).max()) > 0 for x in flat), f"{arch}: zero grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decode after prefill must match the full-sequence forward logits."""
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(2)
    params, _ = lm.init(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]

    extra = batch["embeds"].shape[1] if cfg.family == "vlm" else 0
    cache = lm.init_cache(cfg, B, S + extra + 4)
    if cfg.family == "hybrid":
        # ring caches need prefill >= window; smoke window is 64 <= S
        pass
    last, cache = lm.prefill(cfg, params, batch, cache)
    assert last.shape == (B, cfg.vocab)
    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    logits2, cache = lm.decode_step(cfg, params, nxt, cache)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits2).all())

    # cross-check prefill last-token logits against the pure forward pass
    full, _, _ = lm.forward(cfg, params, tokens,
                            embeds=batch.get("embeds"),
                            enc_frames=batch.get("enc_frames"))
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=2e-2, atol=2e-2)
