"""Randomized packed-vs-reference fused expansion property (hypothesis).

Skips cleanly when ``hypothesis`` is not installed; the deterministic
fused fast-path parity tests live in ``test_fused_fastpath.py`` and always
run.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: pip install hypothesis "
           "(see requirements.txt)")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.einsum import batched_matmul  # noqa: E402
from repro.core.fusion import (FusedWorkload, GroupEdge,  # noqa: E402
                               enumerate_fused_skeletons)
from repro.core.presets import tpu_v4i_like  # noqa: E402
from repro.core.search import cached_curried_model  # noqa: E402
from repro.core.tileshape import stepper_for  # noqa: E402

from test_fused_fastpath import _expand_reference  # noqa: E402

TPU = tpu_v4i_like()


def _stepper(skeleton_idx):
    qk = batched_matmul("pqk", 4, 2, 8, 16)
    av = batched_matmul("pav", 4, 2, 16, 8)
    wl = FusedWorkload("pqk+pav", (qk, av), (GroupEdge(0, 1, "Z", "A"),))
    sks = enumerate_fused_skeletons(wl, TPU)
    return stepper_for(
        cached_curried_model(wl, TPU, sks[skeleton_idx % len(sks)]), "edp")


def _reference_step(stp, k, cols, rem, fan_rem):
    ab = stp.absorber.get(k)
    if ab:
        c = cols.copy()
        c[:, k] = rem[:, ab[0]]
        r = rem.copy()
        r[:, list(ab)] = 1
        return c, r, fan_rem
    chains = stp.site_chains[k]
    shape = stp.chain_shapes[chains[0]]
    divs = np.array([d for d in range(1, shape + 1) if shape % d == 0],
                    dtype=np.int64)
    return _expand_reference(k, divs, list(chains), stp._site_fan_cols[k],
                             cols, rem, fan_rem)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(skeleton_idx=st.integers(min_value=0, max_value=40),
       seed=st.integers(min_value=0, max_value=2**32 - 1),
       cap=st.integers(min_value=4, max_value=48))
def test_packed_and_reference_expansion_identical_frontiers(
        skeleton_idx, seed, cap):
    """At every step of a randomly truncated walk through the explore
    order, the packed ``st.expand`` emits exactly the frontier the
    per-divisor reference loop would — same rows, same order, all three
    arrays (tile columns, chain quotients, fanout capacities)."""
    stp = _stepper(skeleton_idx)
    rng = np.random.default_rng(seed)
    cols, rem, fan_rem = stp.init_state()
    for k in stp.explore_order:
        got = stp.expand(k, cols, rem, fan_rem)
        ref = _reference_step(stp, k, cols, rem, fan_rem)
        if ref is None:
            assert got is None
            return
        assert got is not None
        for g, r in zip(got, ref):
            assert g.dtype == r.dtype
            assert np.array_equal(g, r)
        cols, rem, fan_rem = got
        if cols.shape[0] > cap:  # random truncation, same rows both paths
            sel = np.sort(rng.permutation(cols.shape[0])[:cap])
            cols, rem, fan_rem = cols[sel], rem[sel], fan_rem[sel]
