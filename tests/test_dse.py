"""Design-space exploration correctness oracle + roofline soundness.

The headline contract (PR-5 acceptance): on a small space the explorer with
roofline ordering + cross-point incumbent seeding returns the *same Pareto
frontier* as exhaustive per-point ``tcm_map``, while expanding strictly
fewer total branch-and-bound nodes; serial and process-pool backends are
value-identical.
"""
import pytest

from repro.core.arch import ArchAxis, ArchSpace
from repro.core.einsum import batched_matmul, matmul
from repro.core.mapper import tcm_map, tcm_map_best_arch
from repro.core.presets import nvdla_template, small_matmul_suite
from repro.core.search import clear_search_caches
from repro.dse import (check_parity, einsum_bounds, explore_space,
                       get_space, pareto_keep, resolve_workload)
from repro.netmap.cache import MappingCache

KiW = 2 ** 10


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_search_caches()
    yield
    clear_search_caches()


def tiny_pair():
    """The smoke attention pair (QK -> AV shapes, CI-sized)."""
    return [batched_matmul("fqk", 8, 4, 32, 64),
            batched_matmul("fav", 8, 4, 64, 32)]


def edge8():
    return get_space("edge-small")  # 12 combos -> 8 candidate points


def _frontier_sig(report):
    return sorted((r.arch_key, r.objective, r.energy, r.latency, r.area_mm2)
                  for r in report.frontier)


def _evaluated_sig(report):
    return sorted((r.arch_key, r.status, r.objective, r.energy, r.latency)
                  for r in report.rows)


# --------------------------------------------------------------------------
# Oracle: pruned + seeded explorer == exhaustive per-point search
# --------------------------------------------------------------------------


@pytest.mark.parametrize("objective", ["edp", "energy", "latency"])
def test_explorer_matches_exhaustive_frontier(objective):
    space, einsums = edge8(), tiny_pair()
    fast = explore_space(space, einsums, objective)
    slow = explore_space(space, einsums, objective, prune=False,
                         seed_incumbents=False)
    assert slow.n_evaluated == 8  # oracle really searched every point
    assert fast.n_pruned_roofline + fast.n_pruned_bound > 0
    assert _frontier_sig(fast) == _frontier_sig(slow)
    assert fast.best.arch_key == slow.best.arch_key
    assert fast.best.objective == slow.best.objective
    # bound-based pruning must save work, not just points: strictly fewer
    # total expansions (counters asserted per the acceptance criteria)
    assert fast.n_expanded < slow.n_expanded


def test_explorer_evaluated_points_are_exact():
    """Seeded searches that survive the bound return true per-point optima:
    every evaluated row equals an independent unseeded tcm_map total."""
    space, einsums = edge8(), tiny_pair()
    rep = explore_space(space, einsums)
    points = {p.key: p for p in space.points()}
    checked = 0
    for row in rep.rows:
        if row.status != "evaluated":
            continue
        arch = points[row.arch_key].arch
        energy = latency = 0.0
        for e in einsums:
            best, _ = tcm_map(e, arch, collect_sizes=False)
            energy += best.energy
            latency += best.latency
        assert row.energy == energy
        assert row.latency == latency
        checked += 1
    assert checked >= 2


def test_serial_and_process_pool_value_identical():
    space, einsums = edge8(), tiny_pair()
    serial = explore_space(space, einsums)
    pool = explore_space(space, einsums, workers=2)
    assert _evaluated_sig(pool) == _evaluated_sig(serial)
    assert _frontier_sig(pool) == _frontier_sig(serial)
    assert pool.best.arch_key == serial.best.arch_key


def test_check_parity_helper():
    ok, msg = check_parity(edge8(), tiny_pair(), n_points=3)
    assert ok, msg
    assert "parity ok" in msg


def test_resolve_workload_and_named_spaces():
    es = resolve_workload("QK,FFA")
    assert [e.name for e in es] == ["QK", "FFA"]
    with pytest.raises(KeyError):
        resolve_workload("NOPE")
    assert get_space("edge").size == 16
    with pytest.raises(KeyError):
        get_space("nope")


# --------------------------------------------------------------------------
# Roofline soundness
# --------------------------------------------------------------------------


def test_roofline_bounds_are_sound_floors():
    """No valid mapping may beat the roofline floor on energy or latency —
    checked against the true optimum on every point of the CI space, for
    einsums with and without spatial-discount-eligible tensors."""
    suite = small_matmul_suite()
    einsums = [suite["P0"], tiny_pair()[0]]
    for point in edge8().points():
        for e in einsums:
            b = einsum_bounds(e, point.arch)
            for objective in ("energy", "latency"):
                best, _ = tcm_map(e, point.arch, objective=objective,
                                  collect_sizes=False)
                assert best is not None
                assert b.energy <= best.energy * (1 + 1e-12)
                assert b.latency <= best.latency * (1 + 1e-12)


def test_pareto_keep_semantics():
    pts = [(1.0, 5.0), (2.0, 4.0), (3.0, 3.0), (3.0, 5.0), (1.0, 5.0)]
    keep = pareto_keep(pts)
    # (3,5) dominated by (3,3)/(2,4); exact ties (1,5)&(1,5) both kept
    assert keep == [True, True, True, False, True]


# --------------------------------------------------------------------------
# Cross-arch batched search (tcm_map_best_arch)
# --------------------------------------------------------------------------


def test_tcm_map_best_arch_matches_per_arch_min():
    qk = tiny_pair()[0]
    arches = [p.arch for p in edge8().points()][:4]
    per = []
    for a in arches:
        best, _ = tcm_map(qk, a, collect_sizes=False)
        per.append(best)
    want_idx = min(range(len(per)), key=lambda i: per[i].edp)
    idx, best, stats = tcm_map_best_arch(qk, arches)
    assert idx == want_idx
    assert (best.energy, best.latency, best.edp) == (
        per[want_idx].energy, per[want_idx].latency, per[want_idx].edp)
    assert stats.n_expanded > 0
    # parallel backend returns the same winner
    idx2, best2, _ = tcm_map_best_arch(qk, arches, workers=2)
    assert idx2 == idx and best2.edp == best.edp


def test_tcm_map_seeded_none_is_sound():
    """tcm_map(inc_obj=T): a None (or >= T) result proves the optimum is
    no better than T; a result below T is the exact optimum."""
    qk = tiny_pair()[0]
    arch = edge8().template.instantiate()
    best, _ = tcm_map(qk, arch, collect_sizes=False)
    loose, _ = tcm_map(qk, arch, collect_sizes=False, inc_obj=best.edp * 2)
    assert loose is not None and loose.edp == best.edp
    tight, _ = tcm_map(qk, arch, collect_sizes=False, inc_obj=best.edp / 2)
    assert tight is None or tight.edp >= best.edp / 2


# --------------------------------------------------------------------------
# Warm cache across sweeps
# --------------------------------------------------------------------------


def test_sweep_warm_cache_round_trip(tmp_path):
    space, einsums = edge8(), tiny_pair()
    cache = MappingCache(root=tmp_path)
    cold = explore_space(space, einsums, cache=cache)
    assert cold.cache_misses > 0 and cold.cache_hits == 0
    clear_search_caches()
    warm = explore_space(space, einsums, cache=MappingCache(root=tmp_path))
    # every evaluated point's per-einsum optima come from disk...
    assert warm.cache_misses == 0
    assert warm.cache_hits == sum(r.cached for r in warm.rows)
    assert warm.t_search == 0.0
    # ...and the sweep outcome is identical to the cold run
    assert _evaluated_sig(warm) == _evaluated_sig(cold)
    assert _frontier_sig(warm) == _frontier_sig(cold)
