"""Anytime-search budgets: semantics, no-budget bit-identity, soundness.

Three contracts from the resilience layer:

  * **Off-path identity** — ``budget=None`` (and a never-expiring budget)
    leaves mappings *and* stats bit-identical to the historical search on
    both backends: the metering is observation-only until it fires.
  * **Anytime validity** — a truncated run returns a structurally valid
    mapping whose objective is >= the true optimum (it is a real evaluated
    mapping, never an extrapolation), with ``stats.truncated`` set.
  * **Certificate soundness** — when ``gap_bound`` is finite, the true
    optimum (brute-force oracle) is >= best/gap_bound: the bound really is
    a proof, not a heuristic report.
"""
import pytest

from repro.core.arch import Arch, MemLevel, SpatialFanout
from repro.core.bruteforce import brute_force_optimum
from repro.core.budget import (BudgetMeter, SearchBudget, SharedBudgetMeter,
                               ensure_meter)
from repro.core.einsum import conv1d, matmul
from repro.core.looptree import validate_structure
from repro.core.mapper import tcm_map
from repro.core.search import clear_search_caches

CASES = [
    ("matmul", matmul("mm", 4, 4, 4),
     Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                MemLevel("GLB", 12, 1, 1, 1e9)), mac_energy=0.5)),
    ("conv", conv1d("cv", P=4, R=3, C=2, Kc=2),
     Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                MemLevel("GLB", 16, 1, 1, 1e9)), mac_energy=0.5)),
    ("spatial", matmul("mm", 2, 4, 2),
     Arch("sp", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                 MemLevel("GLB", 24, 1, 1, 1e9)),
          fanouts=(SpatialFanout(above_level=0, dims=(2, 2),
                                 multicast_tensor=("A", None),
                                 reduce_tensor=(None, "Z")),),
          mac_energy=0.5)),
]

# a budget that can never fire within a test run: the off-path contract
# must hold whether no meter exists or a meter exists but never expires
GENEROUS = SearchBudget(deadline_s=3600.0, max_expanded=10 ** 12)

RTOL = 1e-9


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_search_caches()
    yield
    clear_search_caches()


def _stats_sig(stats):
    """Full stats record minus wall-clock timings (those legitimately
    drift run to run)."""
    return {k: v for k, v in stats.to_dict().items()
            if not k.startswith("t_")}


# --------------------------------------------------------------------------
# meter unit semantics
# --------------------------------------------------------------------------


def test_budget_meter_accounting():
    m = SearchBudget(max_expanded=10).start()
    assert isinstance(m, BudgetMeter)
    assert not m.expired() and m.remaining_nodes() == 10
    m.charge(4)
    assert m.remaining_nodes() == 6 and not m.expired()
    m.charge(6)
    assert m.remaining_nodes() == 0 and m.expired()
    m.charge(5)  # over-draw clamps, never goes negative
    assert m.remaining_nodes() == 0 and m.expired()


def test_budget_meter_deadline():
    m = SearchBudget(deadline_s=0.0).start()
    assert m.expired()
    assert m.remaining_nodes() is None  # unbounded on the node axis
    m2 = SearchBudget(deadline_s=3600.0).start()
    assert not m2.expired()


def test_noop_budget_never_expires():
    m = SearchBudget().start()
    m.charge(10 ** 9)
    assert not m.expired()
    assert m.remaining_nodes() is None and m.deadline_epoch is None


def test_ensure_meter_normalization():
    assert ensure_meter(None) is None
    m = ensure_meter(SearchBudget(max_expanded=5))
    assert isinstance(m, BudgetMeter)
    # a live meter passes through untouched: one meter spans many searches
    assert ensure_meter(m) is m


def test_shared_budget_meter_mirrors_driver_view():
    import multiprocessing as mp

    deadline = mp.Value("d", float("inf"), lock=False)
    cap = mp.Value("q", 10, lock=False)
    nodes = mp.Value("q", 0)
    m = SharedBudgetMeter(deadline, cap, nodes)
    assert not m.expired() and m.remaining_nodes() == 10
    m.charge(10)
    assert m.expired() and m.remaining_nodes() == 0
    cap.value = -1  # the "no budget active" sentinel
    assert not m.expired() and m.remaining_nodes() is None


# --------------------------------------------------------------------------
# off-path identity: budget machinery changes nothing until it fires
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name,ein,arch", CASES, ids=[c[0] for c in CASES])
def test_no_budget_bit_identical_serial(name, ein, arch):
    best_ref, st_ref = tcm_map(ein, arch)
    best_b, st_b = tcm_map(ein, arch, budget=GENEROUS)
    assert best_b.mapping == best_ref.mapping
    assert (best_b.energy, best_b.latency, best_b.edp) == (
        best_ref.energy, best_ref.latency, best_ref.edp)
    assert _stats_sig(st_b) == _stats_sig(st_ref)
    assert not st_b.truncated and st_b.gap_bound == 1.0


@pytest.mark.parametrize("name,ein,arch", CASES, ids=[c[0] for c in CASES])
def test_no_budget_bit_identical_pooled(name, ein, arch):
    """The unshared search (exact-stats contract) stays bit-identical
    across backends with a live-but-idle meter installed in the workers."""
    best_s, st_s = tcm_map(ein, arch, share_incumbents=False)
    best_p, st_p = tcm_map(ein, arch, workers=2, share_incumbents=False,
                           budget=GENEROUS)
    assert best_p.mapping == best_s.mapping
    assert (best_p.energy, best_p.latency, best_p.edp) == (
        best_s.energy, best_s.latency, best_s.edp)
    assert _stats_sig(st_p) == _stats_sig(st_s)
    assert not st_p.truncated and st_p.gap_bound == 1.0


# --------------------------------------------------------------------------
# anytime validity + certificate soundness vs the brute-force oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [None, 2], ids=["serial", "pool"])
@pytest.mark.parametrize("cap", [1, 5, 50])
def test_node_cap_truncation_is_sound(workers, cap):
    name, ein, arch = CASES[0]
    oracle = brute_force_optimum(ein, arch, keep_unit_loops=False)
    assert oracle is not None
    best, stats = tcm_map(ein, arch, workers=workers,
                          budget=SearchBudget(max_expanded=cap))
    assert stats.truncated
    assert stats.n_truncated_units > 0
    # the anytime value is a real evaluated mapping: structurally valid
    # and never better than the true optimum
    if best is not None:
        validate_structure(ein, arch, best.mapping)
        assert best.edp >= oracle.result.edp * (1 - RTOL)
        # certificate: optimum >= best / gap_bound (when certifiable)
        if stats.gap_bound != float("inf"):
            assert stats.gap_bound >= 1.0
            assert oracle.result.edp >= (
                best.edp / stats.gap_bound) * (1 - RTOL)
    else:
        # nothing returned => nothing certifiable
        assert stats.gap_bound == float("inf")


def test_expired_deadline_truncates_every_unit():
    name, ein, arch = CASES[0]
    best, stats = tcm_map(ein, arch,
                          budget=SearchBudget(deadline_s=0.0))
    assert stats.truncated
    assert stats.n_truncated_units > 0
    if best is not None:
        validate_structure(ein, arch, best.mapping)


def test_untruncated_budget_run_is_exact():
    """A cap the search never reaches: result must be exact (gap 1.0) and
    equal to the unbudgeted optimum."""
    name, ein, arch = CASES[0]
    ref, _ = tcm_map(ein, arch)
    best, stats = tcm_map(ein, arch, budget=GENEROUS)
    assert not stats.truncated and stats.gap_bound == 1.0
    assert best.edp == ref.edp


def test_one_meter_spans_many_searches():
    """netmap threads one meter across every layer: the second search draws
    down what the first consumed and truncates when the pool is empty."""
    name, ein, arch = CASES[0]
    _, st_ref = tcm_map(ein, arch)
    cap = st_ref.n_expanded + 10  # enough for one full search, not two
    meter = SearchBudget(max_expanded=cap).start()
    _, st1 = tcm_map(ein, arch, budget=meter)
    assert not st1.truncated
    assert meter.used >= st_ref.n_expanded
    _, st2 = tcm_map(ein, arch, budget=meter)
    assert st2.truncated  # the shared pool was (nearly) exhausted
    assert st1.gap_bound == 1.0 and st2.gap_bound >= 1.0


def test_truncated_stats_merge():
    from repro.core.search import MapperStats

    a = MapperStats()
    b = MapperStats(truncated=True, gap_bound=1.5, n_truncated_units=2,
                    n_retried_units=1, n_quarantined_units=1,
                    n_resumed_units=3)
    a.merge(b)
    assert a.truncated and a.gap_bound == 1.5
    assert a.n_truncated_units == 2 and a.n_retried_units == 1
    assert a.n_quarantined_units == 1 and a.n_resumed_units == 3
    # gap bounds combine by max (worst certified gap wins)
    a.merge(MapperStats(truncated=True, gap_bound=1.2))
    assert a.gap_bound == 1.5


def test_budget_spec_is_reusable():
    """A SearchBudget is a spec: each start() opens an independent clock."""
    spec = SearchBudget(max_expanded=7)
    m1, m2 = spec.start(), spec.start()
    m1.charge(7)
    assert m1.expired() and not m2.expired()
