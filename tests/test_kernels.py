"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes and dtypes per the deliverable: every kernel must match its
ref.py oracle across the sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotile import tcm_matmul_tiles
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.ref import attention_ref, matmul_ref

MM_SHAPES = [
    (128, 128, 128),
    (256, 128, 384),
    (512, 256, 128),
    (384, 384, 384),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", MM_SHAPES)
def test_matmul_kernel_matches_ref(shape, dtype):
    M, K, N = shape
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)), dtype)
    b = jnp.asarray(rng.normal(size=(K, N)), dtype)
    out = matmul_pallas(a, b, bm=128, bk=128, bn=128, interpret=True)
    ref = matmul_ref(a, b)
    # abs tolerance dominates: accumulation-order noise near zero entries
    tol = 1e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_matmul_kernel_tcm_tiles():
    """End-to-end: TCM-chosen tiles drive the kernel and match the oracle."""
    M, K, N = 512, 384, 640
    bm, bk, bn = tcm_matmul_tiles(M, K, N, vmem_bytes=1 << 20)
    # tiles must be MXU-aligned and divide (after padding) the problem
    assert bm % 128 == 0 or bm == M
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    # pad to tiles like ops.tcm_matmul does
    from repro.kernels.ops import _pad_to
    ap = _pad_to(_pad_to(a, bm, 0), bk, 1)
    bp = _pad_to(_pad_to(b, bk, 0), bn, 1)
    out = matmul_pallas(ap, bp, bm=bm, bk=bk, bn=bn,
                        interpret=True)[:M, :N]
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


FA_SHAPES = [
    # (B, Sq, Sk, Hq, Hkv, Dh, causal)
    (1, 256, 256, 2, 2, 128, True),
    (2, 128, 256, 4, 2, 128, False),  # GQA + cross-length
    (1, 384, 384, 4, 1, 128, True),   # MQA
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", FA_SHAPES)
def test_flash_attention_kernel_matches_ref(shape, dtype):
    B, Sq, Sk, Hq, Hkv, Dh, causal = shape
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, Dh)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, Dh)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, bq=128, bk=128,
                                 interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
