"""Randomized gap-soundness checks (hypothesis property tests).

Skips cleanly when the optional ``hypothesis`` dependency is not installed;
``pip install hypothesis`` (or ``pip install -r requirements.txt``) enables
it.  The deterministic gap tests live in ``test_gap.py`` and always run;
the CI-scale fuzz sweep is ``python -m repro.gap --mode soundness``.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency: pip install hypothesis "
           "(see requirements.txt)")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.arch import Arch, MemLevel  # noqa: E402
from repro.core.baselines import (evolutionary,  # noqa: E402
                                  simulated_annealing)
from repro.core.einsum import matmul  # noqa: E402
from repro.core.looptree import validate_structure  # noqa: E402
from repro.core.mapper import tcm_map  # noqa: E402

REL_EPS = 1e-9


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    m=st.sampled_from([2, 3, 4]),
    k=st.sampled_from([2, 4]),
    n=st.sampled_from([2, 3]),
    cap=st.sampled_from([8, 16, 64]),
    dram_e=st.sampled_from([50.0, 200.0]),
    objective=st.sampled_from(["edp", "energy", "latency"]),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_property_metaheuristics_never_beat_tcm(m, k, n, cap, dram_e,
                                                objective, seed):
    """SA and the evolutionary mapper search TCM's own mapspace, so no draw
    of (workload, arch, seed) may ever land strictly below ``tcm_map``'s
    optimum — and every best mapping must be structurally legal."""
    ein = matmul("mm", m, k, n)
    arch = Arch("a", (
        MemLevel("DRAM", float("inf"), dram_e, dram_e, 1e8),
        MemLevel("GLB", cap, 1.0, 1.0, 1e9)), mac_energy=0.5)
    best, _ = tcm_map(ein, arch, objective=objective)
    opt = best.objective(objective) if best is not None else float("inf")
    for fn in (simulated_annealing, evolutionary):
        r = fn(ein, arch, budget_evals=30, seed=seed, objective=objective)
        assert r.objective(objective) >= opt * (1 - REL_EPS), \
            f"{fn.__name__} beat the claimed optimum — pruning bug"
        if r.best_mapping is not None:
            validate_structure(ein, arch, r.best_mapping)
