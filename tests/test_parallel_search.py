"""Parallel search engine: parity with the serial backend + executor units.

Two parity contracts:

  * ``share_incumbents=False`` (the historical per-unit-incumbent search) is
    bit-identical across backends: same optimal mapping (same
    EDP/energy/latency, same LoopTree) and the same merged stats.
  * The default shared-incumbent search returns *value-identical* optima
    (energy, latency, edp) across backends and vs the unshared search; its
    prune counters depend on incumbent arrival order, which is deterministic
    serially but scheduling-dependent in the process pool, so only
    driver-side enumeration stats are compared there.
"""
import pickle

import pytest

from repro.core.arch import Arch, MemLevel, SpatialFanout
from repro.core.einsum import conv1d, matmul
from repro.core.mapper import build_work_units, tcm_map
from repro.core.search import (MapperStats, ProcessPoolEngine, SerialEngine,
                               cached_curried_model, cached_dataplacements,
                               cached_skeletons, einsum_key, make_engine,
                               run_work_unit)

CASES = [
    ("matmul", matmul("mm", 4, 4, 4),
     Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                MemLevel("GLB", 12, 1, 1, 1e9)), mac_energy=0.5)),
    ("conv", conv1d("cv", P=4, R=3, C=2, Kc=2),
     Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                MemLevel("GLB", 16, 1, 1, 1e9)), mac_energy=0.5)),
    ("spatial", matmul("mm", 2, 4, 2),
     Arch("sp", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                 MemLevel("GLB", 24, 1, 1, 1e9)),
          fanouts=(SpatialFanout(above_level=0, dims=(2, 2),
                                 multicast_tensor=("A", None),
                                 reduce_tensor=(None, "Z")),),
          mac_energy=0.5)),
]

STAT_FIELDS = (
    "log10_total", "log10_after_df_pruning", "log10_after_loop_pruning",
    "log10_evaluated", "n_dataplacements", "n_skeletons", "n_final_evals",
    "n_expanded", "n_pruned_dominated", "n_pruned_invalid", "n_pruned_bound",
)


DRIVER_STAT_FIELDS = (
    "log10_total", "log10_after_df_pruning", "log10_after_loop_pruning",
    "n_dataplacements", "n_skeletons",
)


@pytest.mark.parametrize("name,ein,arch", CASES, ids=[c[0] for c in CASES])
def test_parallel_matches_serial_unshared(name, ein, arch):
    """The non-shared search stays bit-identical across backends."""
    best_s, st_s = tcm_map(ein, arch, share_incumbents=False)
    best_p, st_p = tcm_map(ein, arch, workers=2, share_incumbents=False)
    assert best_s is not None and best_p is not None
    # bit-identical optimum
    assert best_p.edp == best_s.edp
    assert best_p.energy == best_s.energy
    assert best_p.latency == best_s.latency
    assert best_p.mapping == best_s.mapping
    # exact merged mapspace-size stats
    for f in STAT_FIELDS:
        assert getattr(st_p, f) == getattr(st_s, f), f


@pytest.mark.parametrize("name,ein,arch", CASES, ids=[c[0] for c in CASES])
def test_parallel_matches_serial(name, ein, arch):
    """The default shared-incumbent search is value-identical across
    backends (prune counters may differ with worker scheduling)."""
    best_s, st_s = tcm_map(ein, arch)
    best_p, st_p = tcm_map(ein, arch, workers=2)
    assert best_s is not None and best_p is not None
    assert best_p.edp == best_s.edp
    assert best_p.energy == best_s.energy
    assert best_p.latency == best_s.latency
    for f in DRIVER_STAT_FIELDS:
        assert getattr(st_p, f) == getattr(st_s, f), f


def test_parallel_matches_serial_other_objectives():
    _, ein, arch = CASES[0]
    for objective in ("energy", "latency"):
        best_s, _ = tcm_map(ein, arch, objective=objective)
        best_p, _ = tcm_map(ein, arch, objective=objective, workers=2)
        assert best_p.objective(objective) == best_s.objective(objective)
        best_su, _ = tcm_map(ein, arch, objective=objective,
                             share_incumbents=False)
        best_pu, _ = tcm_map(ein, arch, objective=objective, workers=2,
                             share_incumbents=False)
        assert best_pu.mapping == best_su.mapping
        assert best_su.objective(objective) == best_s.objective(objective)


def test_make_engine_selection():
    assert isinstance(make_engine(), SerialEngine)
    assert isinstance(make_engine(workers=1), SerialEngine)
    assert isinstance(make_engine(workers=3), ProcessPoolEngine)
    assert make_engine(workers=3).workers == 3
    assert isinstance(make_engine(backend="serial", workers=8), SerialEngine)
    assert isinstance(make_engine(backend="process"), ProcessPoolEngine)
    with pytest.raises(ValueError):
        make_engine(backend="gpu")


def test_work_units_picklable_and_runnable():
    _, ein, arch = CASES[0]
    units = build_work_units(ein, arch, "edp", True, True, MapperStats())
    assert units and [u.index for u in units] == list(range(len(units)))
    unit = pickle.loads(pickle.dumps(units[0]))
    result = run_work_unit(unit)
    assert result.index == 0
    blob = pickle.loads(pickle.dumps(result))  # results cross processes too
    assert blob.stats.t_tileshape >= 0.0


def test_stats_merge_is_exact():
    a = MapperStats(n_expanded=3, n_final_evals=1, sum_total=1e-3,
                    t_curry=0.5)
    b = MapperStats(n_expanded=7, n_final_evals=2, sum_total=2e-3,
                    t_curry=0.25)
    a.merge(b)
    assert a.n_expanded == 10
    assert a.n_final_evals == 3
    assert a.sum_total == 3e-3
    assert a.t_curry == 0.75


def test_structural_memoization_shares_across_names():
    """Two einsums differing only in name hit the same cache entries."""
    _, ein, arch = CASES[0]
    renamed = matmul("other_name", 4, 4, 4)
    assert einsum_key(ein) == einsum_key(renamed)
    dps_a = cached_dataplacements(ein, arch)
    dps_b = cached_dataplacements(renamed, arch)
    assert dps_a is dps_b  # same tuple object => cache hit
    sk_a = cached_skeletons(ein, arch, dps_a[0])
    sk_b = cached_skeletons(renamed, arch, dps_a[0])
    assert sk_a is sk_b
    cm_a = cached_curried_model(ein, arch, sk_a[0])
    cm_b = cached_curried_model(renamed, arch, sk_a[0])
    assert cm_a is cm_b
