"""Network mapping pipeline: extraction, graph edges, fusion-aware planner,
dedup, CLI, kernel hook."""
import json

import pytest

from repro.configs import get_config
from repro.core.mapper import tcm_map
from repro.core.presets import nvdla_like
from repro.core.search import einsum_key
from repro.netmap import (MappingCache, extract_einsums, extract_graph,
                          map_network)
from repro.netmap.__main__ import main as netmap_main

ARCH = nvdla_like(tensors=("A", "B", "Z"))


def _edge(graph, producer_op, consumer_op, layer_tag):
    """The edge between two ops of one layer (None if absent)."""
    for e in graph.edges:
        if e.producer.endswith(f"{layer_tag}.{producer_op}") and \
                e.consumer.endswith(f"{layer_tag}.{consumer_op}"):
            return e
    return None  # matmul tensor names


# --------------------------------------------------------------------------
# extraction
# --------------------------------------------------------------------------


def test_extract_qwen_prefill_dedups_to_six():
    cfg = get_config("qwen1_5_0_5b")
    entries = extract_einsums(cfg, mode="prefill", batch=1, seq=1024)
    # 24 layers x (3 qkv + 2 attn + o + 3 ffn) + lm_head
    assert len(entries) == cfg.n_layers * 9 + 1
    unique = {einsum_key(e.einsum) for e in entries}
    # q/k/v/o projections share one shape (q_dim == kv_dim == d_model) and
    # ffn up/gate share one: proj, qk, av, ffn_up, ffn_down, lm_head
    assert len(unique) == 6


def test_extract_decode_shapes():
    cfg = get_config("qwen1_5_0_5b")
    entries = extract_einsums(cfg, mode="decode", batch=4, seq=256)
    by_op = {e.op: e for e in entries if e.layer == 0}
    assert by_op["q_proj"].einsum.rank_shapes["m"] == 4  # one token/seq
    qk = by_op["qk"].einsum.rank_shapes
    assert qk["m"] == 1 and qk["n"] == 256  # new token vs KV cache
    assert qk["h"] == 4 * cfg.n_heads


def test_extract_ssm_path():
    cfg = get_config("mamba2_130m")
    entries = extract_einsums(cfg, mode="prefill", batch=1, seq=512)
    ops = {e.op for e in entries}
    assert {"ssm_in_proj", "ssd_qk", "ssd_av", "ssm_out_proj"} <= ops
    assert "q_proj" not in ops and "ffn_up" not in ops  # d_ff == 0


def test_extract_moe_expert_counts():
    cfg = get_config("phi3_5_moe_42b")
    entries = extract_einsums(cfg, mode="prefill", batch=1, seq=128)
    ffn = [e for e in entries if e.op == "ffn_up" and e.layer == 0]
    assert len(ffn) == 1 and ffn[0].count == cfg.n_experts
    # per-expert tokens ~ tokens * top_k / n_experts
    assert ffn[0].einsum.rank_shapes["m"] == 128 * cfg.top_k // cfg.n_experts


def test_extract_hybrid_block_pattern():
    cfg = get_config("recurrentgemma_2b", smoke=True)
    entries = extract_einsums(cfg, mode="prefill", batch=1, seq=128)
    by_layer = {}
    for e in entries:
        by_layer.setdefault(e.layer, set()).add(e.op)
    # pattern is (rglru, rglru, wattn)
    assert "rg_in_proj" in by_layer[0] and "q_proj" not in by_layer[0]
    assert "q_proj" in by_layer[2] and "rg_in_proj" not in by_layer[2]


def test_extract_moe_scarce_tokens_not_overcounted():
    cfg = get_config("phi3_5_moe_42b")  # 16 experts, top-2
    entries = extract_einsums(cfg, mode="decode", batch=2, seq=128)
    ffn = next(e for e in entries if e.op == "ffn_up" and e.layer == 0)
    # 2 tokens x top-2 = 4 expert-token pairs: only 4 experts see work
    assert ffn.count == 4 and ffn.einsum.rank_shapes["m"] == 1
    # indivisible pairs round up, never undercount: 3x2=6 pairs, 16 experts
    entries = extract_einsums(cfg, mode="decode", batch=3, seq=128)
    ffn = next(e for e in entries if e.op == "ffn_up" and e.layer == 0)
    assert ffn.count * ffn.einsum.rank_shapes["m"] >= 6


def test_extract_encdec():
    cfg = get_config("seamless_m4t_medium")
    prefill = extract_einsums(cfg, mode="prefill", batch=1, seq=64)
    ops_by_layer = {}
    for e in prefill:
        ops_by_layer.setdefault(e.layer, set()).add(e.op)
    # encoder layers: self-attention only; decoder layers add cross-attn
    assert "xqk" not in ops_by_layer[0] and "qk" in ops_by_layer[0]
    dec0 = cfg.enc_layers
    assert {"qk", "xqk", "xk_proj", "xav"} <= ops_by_layer[dec0]
    assert max(ops_by_layer) + 1 == cfg.enc_layers + cfg.dec_layers

    decode = extract_einsums(cfg, mode="decode", batch=1, seq=64)
    dec_ops = {e.op for e in decode}
    # encoder stack + cross-K/V ran at prefill; not charged per step
    assert all(e.layer >= dec0 or e.layer == -1 for e in decode)
    assert "xk_proj" not in dec_ops and "xqk" in dec_ops


# --------------------------------------------------------------------------
# workload graph edges
# --------------------------------------------------------------------------


def test_graph_edges_dense_attention_and_ffn():
    ng = extract_graph(get_config("qwen1_5_0_5b"), mode="prefill", batch=1,
                       seq=256)
    g = ng.graph
    qk_av = _edge(g, "qk", "av", "L0")
    assert qk_av is not None and qk_av.fusable
    assert g.edge_fusable(qk_av, ARCH)
    # the gated-FFN chain: up -> down and gate -> down, both fusable
    for producer in ("ffn_up", "ffn_gate"):
        e = _edge(g, producer, "ffn_down", "L0")
        assert e is not None and e.fusable and g.edge_fusable(e, ARCH)
    # reshape boundaries are recorded but vetoed
    e = _edge(g, "q_proj", "qk", "L0")
    assert e is not None and not e.fusable and "reshape" in e.reason


def test_graph_edges_moe_routing_not_fusable():
    ng = extract_graph(get_config("phi3_5_moe_42b"), mode="prefill",
                       batch=1, seq=128)
    g = ng.graph
    for producer in ("ffn_up", "ffn_gate"):
        e = _edge(g, producer, "ffn_down", "L0")
        assert e is not None and not e.fusable
        assert "routing" in e.reason
        assert not g.edge_fusable(e, ARCH)
    # MoE attention still fuses QK->AV
    e = _edge(g, "qk", "av", "L0")
    assert e is not None and g.edge_fusable(e, ARCH)


def test_graph_edges_encdec_cross_attention_not_fusable():
    ng = extract_graph(get_config("seamless_m4t_medium"), mode="decode",
                       batch=1, seq=64)
    g = ng.graph
    e = _edge(g, "xqk", "xav", "dec0")
    assert e is not None and not e.fusable
    assert "encoder" in e.reason
    assert not g.edge_fusable(e, ARCH)
    # decoder self-attention fuses as usual
    e = _edge(g, "qk", "av", "dec0")
    assert e is not None and g.edge_fusable(e, ARCH)


def test_graph_edges_ssm_and_rglru():
    g = extract_graph(get_config("mamba2_130m"), mode="prefill", batch=1,
                      seq=512).graph
    e = _edge(g, "ssd_qk", "ssd_av", "L0")
    assert e is not None and e.fusable
    g = extract_graph(get_config("recurrentgemma_2b", smoke=True),
                      mode="prefill", batch=1, seq=128).graph
    e = _edge(g, "rg_in_proj", "rg_out_proj", "L0")
    assert e is not None and not e.fusable and "recurrence" in e.reason


def test_graph_partition_covers_every_node():
    ng = extract_graph(get_config("qwen1_5_0_5b", smoke=True),
                       mode="decode", batch=2, seq=32)
    groups = ng.graph.partition_fusion_groups(ARCH)
    names = [n for grp in groups for n in grp.members]
    assert sorted(names) == sorted(n.name for n in ng.graph.nodes)
    fused = [grp for grp in groups if grp.is_fused]
    labels = {"+".join(ng.entry(n).op for n in grp.members)
              for grp in fused}
    assert "qk+av" in labels
    assert "ffn_up+ffn_gate+ffn_down" in labels


def test_extract_rejects_bad_args():
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    with pytest.raises(ValueError):
        extract_einsums(cfg, mode="training")
    with pytest.raises(ValueError):
        extract_einsums(cfg, batch=0)


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------


def _smoke_report(cache=None, **kw):
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    return map_network(cfg, ARCH, mode="decode", batch=2, seq=32,
                       cache=cache, **kw)


def test_map_network_totals_consistent():
    entries = extract_einsums(get_config("qwen1_5_0_5b", smoke=True),
                              mode="decode", batch=2, seq=32)
    rep = _smoke_report(fuse=False)
    assert len(rep.rows) == len(entries)
    assert len(rep.unique) < len(rep.rows)
    assert rep.total_energy == pytest.approx(sum(r.energy for r in rep.rows))
    assert rep.total_latency == pytest.approx(
        sum(r.latency for r in rep.rows))
    assert rep.total_edp == rep.total_energy * rep.total_latency
    assert rep.total_edp > 0 and rep.log10_mapspace > 0
    # per-layer totals cover every layer plus the LM head (-1)
    layers = [layer for layer, *_ in rep.layer_totals()]
    assert layers == sorted(set(r.layer for r in rep.rows))

    # with fusion, adopted groups fold member ops into one row each but the
    # totals stay internally consistent and never exceed the baseline
    fused = _smoke_report()
    folded = sum((f.n_instances * (len(f.ops.split("+")) - 1))
                 for f in fused.fused if f.adopted)
    assert len(fused.rows) == len(entries) - folded
    assert fused.total_energy == pytest.approx(
        sum(r.energy for r in fused.rows))
    assert fused.total_latency == pytest.approx(
        sum(r.latency for r in fused.rows))
    assert fused.total_energy <= rep.total_energy
    assert fused.total_latency <= rep.total_latency


def test_map_network_report_serializes():
    rep = _smoke_report()
    d = rep.to_dict()
    json.dumps(d)  # JSON-safe
    assert d["totals"]["edp_pJs"] == rep.total_edp
    text = rep.render()
    assert "network totals" in text and "hit rate" in text


def test_map_network_cache_roundtrip_identical(tmp_path):
    cold = _smoke_report(cache=MappingCache(root=tmp_path))
    assert cold.cache_hits == 0
    # fusion-group searches miss (and persist) alongside the singletons
    assert cold.cache_misses == len(cold.unique) + len(cold.fused)

    warm = _smoke_report(cache=MappingCache(root=tmp_path))  # re-read disk
    assert warm.cache_misses == 0
    assert warm.cache_hits == len(warm.unique) + len(warm.fused)
    assert warm.cache_hit_rate == 1.0
    # bit-identical composition from cached mappings
    assert warm.total_energy == cold.total_energy
    assert warm.total_latency == cold.total_latency
    assert warm.total_edp == cold.total_edp
    for u_cold, u_warm in zip(cold.unique, warm.unique):
        assert u_warm.result == u_cold.result
        assert u_warm.cached and not u_cold.cached
    for f_cold, f_warm in zip(cold.fused, warm.fused):
        assert f_warm.result == f_cold.result
        assert f_warm.adopted == f_cold.adopted
        assert f_warm.cached and not f_cold.cached


def test_map_network_reused_cache_reports_per_call_deltas(tmp_path):
    cache = MappingCache(root=tmp_path)
    cold = _smoke_report(cache=cache)
    warm = _smoke_report(cache=cache)  # same instance, all hits
    n_cold = len(cold.unique) + len(cold.fused)
    assert cold.cache_hits == 0 and cold.cache_misses == n_cold
    assert warm.cache_hits == n_cold and warm.cache_misses == 0
    assert warm.cache_hit_rate == 1.0


def test_no_fuse_reproduces_per_einsum_composition_bit_for_bit():
    """fuse=False is the independent per-layer planner of old: every row
    and total must equal the manual per-einsum tcm_map composition exactly,
    search stats included."""
    cfg = get_config("qwen1_5_0_5b", smoke=True)
    rep = map_network(cfg, ARCH, mode="decode", batch=2, seq=32, fuse=False)
    assert rep.fused == []

    entries = extract_einsums(cfg, mode="decode", batch=2, seq=32)
    ref = {}
    for e in entries:
        key = einsum_key(e.einsum)
        if key not in ref:
            ref[key] = tcm_map(e.einsum, ARCH, objective="edp")
    assert len(ref) == len(rep.unique)
    for u, key in zip(rep.unique, ref):
        best, stats = ref[key]
        assert u.result.mapping == best.mapping
        assert (u.result.energy, u.result.latency, u.result.edp) == (
            best.energy, best.latency, best.edp)
        # exact stats parity (counters; timings are wall-clock)
        for f in ("n_dataplacements", "n_skeletons", "n_final_evals",
                  "n_expanded", "n_pruned_dominated", "n_pruned_invalid",
                  "n_pruned_bound", "log10_total", "log10_evaluated"):
            assert getattr(u.stats, f) == getattr(stats, f), f

    total_e = total_l = 0.0
    for e in entries:
        best, _ = ref[einsum_key(e.einsum)]
        total_e += best.energy * e.count
        total_l += best.latency * e.count
    assert rep.total_energy == total_e
    assert rep.total_latency == total_l
    assert rep.total_edp == total_e * total_l


def test_fused_planner_beats_or_matches_baseline():
    fused = _smoke_report()
    baseline = _smoke_report(fuse=False)
    assert fused.total_energy <= baseline.total_energy
    assert fused.total_latency <= baseline.total_latency
    # fusion keeps the attention logits + FFN activations off DRAM here, so
    # the network EDP is *strictly* below the independent-mapping baseline
    assert fused.total_edp < baseline.total_edp
    # qwen smoke fuses qk+av and the FFN chain; at least one group adopts
    # and improves EDP strictly
    adopted = [f for f in fused.fused if f.adopted]
    assert adopted and any(f.edp_delta > 0 for f in adopted)
    # adopted groups report a real pin level and a fused row in the table
    for f in adopted:
        assert f.pin_level is not None and f.pin_level >= 1
    assert any(r.fused for r in fused.rows)


def test_fused_rows_keep_intermediates_off_dram():
    from repro.core.looptree import Storage

    rep = _smoke_report()
    for f in rep.fused:
        if f.result is None:
            continue
        fm = f.result.mapping
        for i, mapping in enumerate(fm.members):
            for n in mapping:
                if isinstance(n, Storage) and (i, n.tensor) in fm.pinned:
                    assert n.level >= fm.pin_level > 0


# --------------------------------------------------------------------------
# CLI + kernel hook
# --------------------------------------------------------------------------


def test_cli_fast_smoke(tmp_path, capsys):
    args = ["--config", "qwen1_5_0_5b", "--fast",
            "--cache-dir", str(tmp_path / "cache"),
            "--json", str(tmp_path / "report.json")]
    assert netmap_main(args) == 0
    out = capsys.readouterr().out
    assert "network totals" in out and "hit rate 0%" in out

    assert netmap_main(args) == 0  # second run: all cache hits
    out = capsys.readouterr().out
    assert "hit rate 100%" in out and "persistent cache" in out
    report = json.loads((tmp_path / "report.json").read_text())
    assert report["cache"]["hit_rate"] == 1.0


def test_model_blockspec_tiles_hook():
    from repro.core.autotile import tcm_model_tiles

    cfg = get_config("qwen1_5_0_5b", smoke=True)
    tiles = tcm_model_tiles(cfg, mode="decode", batch=2, seq=64)
    assert "L0.q_proj" in tiles and "head.lm_head" in tiles
    for (bm, bk, bn) in tiles.values():
        assert bm >= 1 and bk >= 1 and bn >= 1
    # attention matmuls are tiled per head: m is the decode token count
    assert tiles["L0.qk"][0] <= 2
