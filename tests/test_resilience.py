"""Fault-tolerant execution + checkpoint/resume: the resilience layer.

Covers the PR's acceptance criteria directly:

  * a scripted worker crash mid-search still returns the value-identical
    optimum (bounded retry on a fresh pool, nonzero recovery counters);
  * a poison unit is quarantined after bounded retries with a replayable
    JSON repro, and the run's certificate honestly degrades to
    ``gap_bound=inf`` (one subtree was never searched);
  * engines journal finished work units so an interrupted run resumes
    without re-searching, and a SIGINT'd DSE sweep reaches the same
    Pareto frontier as an uninterrupted one;
  * engine lifecycle is safe: context-manager protocol, idempotent close.

Fault scripting uses ``repro.testing.faults`` (marker-file claims =>
exactly-n-times semantics across processes and retries).
"""
import os

import pytest

from repro.core.arch import Arch, MemLevel
from repro.core.budget import SearchBudget
from repro.core.einsum import batched_matmul, matmul
from repro.core.journal import SearchCheckpoint, replay_unit, unit_from_repro
from repro.core.mapper import tcm_map
from repro.core.search import (ProcessPoolEngine, SerialEngine,
                               clear_search_caches)
from repro.testing.faults import installed, write_plan

EINSUM = matmul("mm", 4, 4, 4)
ARCH = Arch("a", (MemLevel("DRAM", float("inf"), 100, 100, 1e8),
                  MemLevel("GLB", 12, 1, 1, 1e9)), mac_energy=0.5)


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_search_caches()
    yield
    clear_search_caches()


def _values(r):
    return (r.energy, r.latency, r.edp)


# --------------------------------------------------------------------------
# engine lifecycle
# --------------------------------------------------------------------------


def test_engines_are_context_managers():
    with SerialEngine() as eng:
        best, _ = tcm_map(EINSUM, ARCH, engine=eng)
    assert best is not None
    with ProcessPoolEngine(workers=2) as eng:
        best_p, _ = tcm_map(EINSUM, ARCH, engine=eng)
    assert _values(best_p) == _values(best)


def test_pool_close_is_idempotent():
    eng = ProcessPoolEngine(workers=2)
    best, _ = tcm_map(EINSUM, ARCH, engine=eng)
    assert best is not None
    eng.close()
    eng.close()  # second close is a no-op, not an error
    with ProcessPoolEngine(workers=2) as eng2:
        pass
    eng2.close()  # close after __exit__ likewise


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------


def test_worker_crash_recovers_value_identical(tmp_path):
    ref, _ = tcm_map(EINSUM, ARCH)
    plan = write_plan(tmp_path / "plan.json", tmp_path / "state",
                      crash={0: 1})
    with installed(plan):
        with ProcessPoolEngine(workers=2) as eng:
            got, stats = tcm_map(EINSUM, ARCH, engine=eng)
            recovered = (eng.fault_stats["retries"]
                         + eng.fault_stats["serial_fallbacks"])
    assert got is not None and _values(got) == _values(ref)
    assert recovered > 0
    assert stats.n_retried_units > 0
    assert eng.fault_stats["quarantined"] == 0
    assert not stats.truncated  # every unit finished (on retry)


def test_crash_markers_make_faults_one_shot(tmp_path):
    """The same plan fires exactly once: a second run under it is clean."""
    plan = write_plan(tmp_path / "plan.json", tmp_path / "state",
                      crash={0: 1})
    with installed(plan):
        with ProcessPoolEngine(workers=2) as eng:
            tcm_map(EINSUM, ARCH, engine=eng)
            first = dict(eng.fault_stats)
        with ProcessPoolEngine(workers=2) as eng2:
            got, _ = tcm_map(EINSUM, ARCH, engine=eng2)
            second = dict(eng2.fault_stats)
    assert first["retries"] > 0 or first["serial_fallbacks"] > 0
    assert second == {"retries": 0, "pool_restarts": 0,
                      "serial_fallbacks": 0, "quarantined": 0}
    assert got is not None


def test_poison_unit_quarantined_with_replayable_repro(tmp_path):
    qdir = tmp_path / "quarantine"
    plan = write_plan(tmp_path / "plan.json", tmp_path / "state",
                      exc={1: 999})  # deterministic: fails every attempt
    with installed(plan):
        with ProcessPoolEngine(workers=2, quarantine_dir=str(qdir)) as eng:
            got, stats = tcm_map(EINSUM, ARCH, engine=eng)
            q = eng.fault_stats["quarantined"]
    assert q >= 1
    assert stats.n_quarantined_units >= 1
    # the certificate honestly degrades: one subtree was never searched
    assert stats.truncated and stats.gap_bound == float("inf")
    repros = sorted(os.listdir(qdir))
    assert len(repros) == q
    # the repro is self-contained and replayable (outside the fault plan
    # it runs clean and yields the unit's real result)
    path = qdir / repros[0]
    import json

    rec = json.loads(path.read_text())
    unit = unit_from_repro(rec)
    assert dict(unit.einsum.rank_shapes) == dict(EINSUM.rank_shapes)
    result = replay_unit(path)
    assert result.candidate is not None or result.stats.n_expanded >= 0


def test_injected_interrupt_surfaces_to_caller(tmp_path):
    """KeyboardInterrupt is never swallowed by tcm_map itself — drivers
    with partial-report semantics (netmap, dse) catch it above."""
    plan = write_plan(tmp_path / "plan.json", tmp_path / "state",
                      interrupt={0: 1})
    with installed(plan):
        with pytest.raises(KeyboardInterrupt):
            tcm_map(EINSUM, ARCH)
        # the marker is consumed: the retry completes normally
        best, _ = tcm_map(EINSUM, ARCH)
    assert best is not None


# --------------------------------------------------------------------------
# checkpoint / resume
# --------------------------------------------------------------------------


def test_serial_checkpoint_resume_value_identical(tmp_path):
    ref, st_ref = tcm_map(EINSUM, ARCH)
    ck = SearchCheckpoint(root=tmp_path)
    best1, st1 = tcm_map(EINSUM, ARCH, checkpoint=ck)
    assert ck.puts > 0 and st1.n_resumed_units == 0
    assert _values(best1) == _values(ref)

    # a fresh process would re-open the journal from disk
    ck2 = SearchCheckpoint(root=tmp_path)
    assert len(ck2) == ck.puts
    best2, st2 = tcm_map(EINSUM, ARCH, checkpoint=ck2)
    assert ck2.hits > 0
    assert st2.n_resumed_units == ck2.hits
    assert best2.mapping == ref.mapping
    assert _values(best2) == _values(ref)


def test_pool_checkpoint_resume_value_identical(tmp_path):
    ref, _ = tcm_map(EINSUM, ARCH)
    ck = SearchCheckpoint(root=tmp_path)
    with ProcessPoolEngine(workers=2, checkpoint=ck) as eng:
        best1, _ = tcm_map(EINSUM, ARCH, engine=eng)
    assert ck.puts > 0
    assert _values(best1) == _values(ref)

    ck2 = SearchCheckpoint(root=tmp_path)
    with ProcessPoolEngine(workers=2, checkpoint=ck2) as eng:
        best2, st2 = tcm_map(EINSUM, ARCH, engine=eng)
    assert ck2.hits > 0 and st2.n_resumed_units == ck2.hits
    assert _values(best2) == _values(ref)


def test_truncated_results_are_not_journaled(tmp_path):
    """Budget-expired units must be re-run on resume, so journaling them
    would defeat the point."""
    ck = SearchCheckpoint(root=tmp_path)
    _, stats = tcm_map(EINSUM, ARCH, budget=SearchBudget(deadline_s=0.0),
                       checkpoint=ck)
    assert stats.truncated
    assert ck.puts == 0
    assert len(SearchCheckpoint(root=tmp_path)) == 0


def test_checkpoint_key_ignores_names_but_not_structure(tmp_path):
    """Checkpoint addressing follows the cache's structural-identity
    discipline: renames hit, shape changes miss."""
    ck = SearchCheckpoint(root=tmp_path)
    tcm_map(EINSUM, ARCH, checkpoint=ck)
    n = ck.puts

    ck2 = SearchCheckpoint(root=tmp_path)
    _, st = tcm_map(matmul("renamed", 4, 4, 4), ARCH, checkpoint=ck2)
    assert ck2.hits == n and st.n_resumed_units == n

    ck3 = SearchCheckpoint(root=tmp_path)
    tcm_map(matmul("mm", 4, 4, 8), ARCH, checkpoint=ck3)
    assert ck3.hits == 0


def test_checkpoint_survives_torn_trailing_line(tmp_path):
    from repro.testing.faults import tear_last_line

    ck = SearchCheckpoint(root=tmp_path)
    tcm_map(EINSUM, ARCH, checkpoint=ck)
    assert ck.puts >= 2  # need a survivor after tearing the last line
    tear_last_line(ck.path)
    reloaded = SearchCheckpoint(root=tmp_path)
    assert reloaded.n_corrupt == 1
    assert len(reloaded) == ck.puts - 1


def test_sigint_then_resume_dse_reaches_same_frontier(tmp_path):
    """A Ctrl-C'd DSE sweep resumed from its journal ends on the same
    Pareto frontier as an uninterrupted sweep."""
    from repro.dse import explore_space, get_space

    space = get_space("edge-small")
    einsums = [batched_matmul("fqk", 8, 4, 32, 64),
               batched_matmul("fav", 8, 4, 64, 32)]

    def sig(report):
        return sorted((r.arch_key, r.objective, r.energy, r.latency)
                      for r in report.frontier)

    base = explore_space(space, einsums, "edp")
    assert not base.interrupted and base.frontier

    # interrupt mid-search of the first point (unit 2 of its first einsum):
    # units 0-1 are already journaled when the SIGINT lands
    plan = write_plan(tmp_path / "plan.json", tmp_path / "state",
                      interrupt={2: 1})
    ck = SearchCheckpoint(root=tmp_path)
    with installed(plan):
        partial = explore_space(space, einsums, "edp", checkpoint=ck)
    assert partial.interrupted
    assert ck.puts > 0
    assert len(partial.frontier) == 0 or sig(partial) != sig(base)

    ck2 = SearchCheckpoint(root=tmp_path)
    resumed = explore_space(space, einsums, "edp", checkpoint=ck2)
    assert not resumed.interrupted
    assert ck2.hits > 0  # journaled units were served, not re-searched
    assert sig(resumed) == sig(base)
